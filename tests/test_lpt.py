"""LPT scheduler tests — Algorithm 2 + Theorem 4 (incl. hypothesis)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.lpt import (
    load_mse,
    lpt_schedule,
    lpt_schedule_jax,
    normalized_load_mse,
    random_schedule,
    round_robin_schedule,
)
from repro.core.theorems import lpt_makespan_bound, theorem4_mse_bound


def test_basic_assignment():
    res = lpt_schedule(np.array([5.0, 3.0, 2.0, 2.0]), 2)
    assert sorted(res.loads.tolist()) == [5.0, 7.0] or sorted(res.loads.tolist()) == [
        6.0,
        6.0,
    ]
    assert res.assignment.shape == (4,)
    np.testing.assert_allclose(res.loads.sum(), 12.0)


def test_every_flow_assigned_exactly_once():
    w = np.random.default_rng(0).exponential(1.0, 100)
    res = lpt_schedule(w, 7)
    loads = np.zeros(7)
    np.add.at(loads, res.assignment, w)
    np.testing.assert_allclose(loads, res.loads)


def test_empty_flows():
    res = lpt_schedule(np.array([]), 4)
    assert res.loads.tolist() == [0.0] * 4


def test_negative_weight_rejected():
    with pytest.raises(ValueError):
        lpt_schedule(np.array([1.0, -2.0]), 2)


def test_device_matches_host():
    rng = np.random.default_rng(3)
    for n in (2, 4, 8):
        w = rng.exponential(10.0, 64)
        host = lpt_schedule(w, n)
        a, loads, mse = lpt_schedule_jax(jnp.asarray(w, jnp.float32), n)
        assert (np.asarray(a) == host.assignment).all()
        np.testing.assert_allclose(np.asarray(loads), host.loads, rtol=1e-5)


def test_lpt_beats_round_robin_on_skew():
    # One elephant + many mice: round-robin collides, LPT spreads.
    w = np.array([100.0] + [1.0] * 63)
    lpt = lpt_schedule(w, 8)
    rr = round_robin_schedule(w, 8)
    assert lpt.loads.max() <= rr.loads.max()
    assert lpt.mse <= rr.mse


def test_normalized_mse_bounds():
    assert normalized_load_mse(np.array([4.0, 4.0, 4.0, 4.0])) == 0.0
    assert abs(normalized_load_mse(np.array([16.0, 0, 0, 0])) - 1.0) < 1e-12
    w = np.random.default_rng(0).uniform(1, 5, 16)
    assert 0.0 <= normalized_load_mse(w) <= 1.0


@settings(max_examples=200, deadline=None)
@given(
    weights=st.lists(st.floats(0.01, 1e3), min_size=1, max_size=200),
    n=st.integers(2, 16),
)
def test_theorem4_property(weights, n):
    """MSE <= w_max^2 for every instance (Theorem 4)."""
    w = np.asarray(weights)
    res = lpt_schedule(w, n)
    mse, bound, holds = theorem4_mse_bound(res.loads, w.max())
    assert holds, (mse, bound)


@settings(max_examples=200, deadline=None)
@given(
    weights=st.lists(st.floats(0.01, 1e3), min_size=1, max_size=200),
    n=st.integers(2, 16),
)
def test_graham_makespan_property(weights, n):
    """Greedy/LPT additive bound (eq. 38): L_max <= mean + (1-1/N)*w_max."""
    w = np.asarray(weights)
    res = lpt_schedule(w, n)
    assert res.loads.max() <= w.sum() / n + (1 - 1 / n) * w.max() + 1e-6
    # and the ratio bound against the OPT lower bound max(mean, w_max),
    # which holds whenever LPT is exactly optimal OR bounded by Graham:
    lower = max(w.sum() / n, w.max())
    assert res.loads.max() <= max(lower * lpt_makespan_bound(n), lower + w.max()) + 1e-6


@settings(max_examples=100, deadline=None)
@given(
    weights=st.lists(st.floats(0.01, 100.0), min_size=2, max_size=100),
    n=st.integers(2, 8),
    seed=st.integers(0, 10),
)
def test_lpt_no_worse_than_random(weights, n, seed):
    w = np.asarray(weights)
    lpt = lpt_schedule(w, n)
    rnd = random_schedule(w, n, seed=seed)
    assert lpt.loads.max() <= rnd.loads.max() + 1e-9


def test_initial_loads_respected():
    # Rail 0 pre-charged: flows avoid it (straggler mitigation hook).
    res = lpt_schedule(np.ones(4), 2, initial_loads=np.array([100.0, 0.0]))
    assert (res.assignment == 1).all()
