"""LPT scheduler tests — Algorithm 2 + Theorem 4 (incl. hypothesis)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.lpt import (
    LptState,
    load_mse,
    lpt_schedule,
    lpt_schedule_jax,
    lpt_schedule_reference,
    normalized_load_mse,
    random_schedule,
    round_robin_schedule,
)
from repro.core.theorems import lpt_makespan_bound, theorem4_mse_bound


def test_basic_assignment():
    res = lpt_schedule(np.array([5.0, 3.0, 2.0, 2.0]), 2)
    assert sorted(res.loads.tolist()) == [5.0, 7.0] or sorted(res.loads.tolist()) == [
        6.0,
        6.0,
    ]
    assert res.assignment.shape == (4,)
    np.testing.assert_allclose(res.loads.sum(), 12.0)


def test_every_flow_assigned_exactly_once():
    w = np.random.default_rng(0).exponential(1.0, 100)
    res = lpt_schedule(w, 7)
    loads = np.zeros(7)
    np.add.at(loads, res.assignment, w)
    np.testing.assert_allclose(loads, res.loads)


def test_empty_flows():
    res = lpt_schedule(np.array([]), 4)
    assert res.loads.tolist() == [0.0] * 4


def test_negative_weight_rejected():
    with pytest.raises(ValueError):
        lpt_schedule(np.array([1.0, -2.0]), 2)


def test_device_matches_host():
    rng = np.random.default_rng(3)
    for n in (2, 4, 8):
        w = rng.exponential(10.0, 64)
        host = lpt_schedule(w, n)
        a, loads, mse = lpt_schedule_jax(jnp.asarray(w, jnp.float32), n)
        assert (np.asarray(a) == host.assignment).all()
        np.testing.assert_allclose(np.asarray(loads), host.loads, rtol=1e-5)


def test_lpt_beats_round_robin_on_skew():
    # One elephant + many mice: round-robin collides, LPT spreads.
    w = np.array([100.0] + [1.0] * 63)
    lpt = lpt_schedule(w, 8)
    rr = round_robin_schedule(w, 8)
    assert lpt.loads.max() <= rr.loads.max()
    assert lpt.mse <= rr.mse


def test_normalized_mse_bounds():
    assert normalized_load_mse(np.array([4.0, 4.0, 4.0, 4.0])) == 0.0
    assert abs(normalized_load_mse(np.array([16.0, 0, 0, 0])) - 1.0) < 1e-12
    w = np.random.default_rng(0).uniform(1, 5, 16)
    assert 0.0 <= normalized_load_mse(w) <= 1.0


@settings(max_examples=200, deadline=None)
@given(
    weights=st.lists(st.floats(0.01, 1e3), min_size=1, max_size=200),
    n=st.integers(2, 16),
)
def test_theorem4_property(weights, n):
    """MSE <= w_max^2 for every instance (Theorem 4)."""
    w = np.asarray(weights)
    res = lpt_schedule(w, n)
    mse, bound, holds = theorem4_mse_bound(res.loads, w.max())
    assert holds, (mse, bound)


@settings(max_examples=200, deadline=None)
@given(
    weights=st.lists(st.floats(0.01, 1e3), min_size=1, max_size=200),
    n=st.integers(2, 16),
)
def test_graham_makespan_property(weights, n):
    """Greedy/LPT additive bound (eq. 38): L_max <= mean + (1-1/N)*w_max."""
    w = np.asarray(weights)
    res = lpt_schedule(w, n)
    assert res.loads.max() <= w.sum() / n + (1 - 1 / n) * w.max() + 1e-6
    # and the ratio bound against the OPT lower bound max(mean, w_max),
    # which holds whenever LPT is exactly optimal OR bounded by Graham:
    lower = max(w.sum() / n, w.max())
    assert res.loads.max() <= max(lower * lpt_makespan_bound(n), lower + w.max()) + 1e-6


@settings(max_examples=100, deadline=None)
@given(
    weights=st.lists(st.floats(0.01, 100.0), min_size=2, max_size=100),
    n=st.integers(2, 8),
    seed=st.integers(0, 10),
)
def test_lpt_no_worse_than_random(weights, n, seed):
    w = np.asarray(weights)
    lpt = lpt_schedule(w, n)
    rnd = random_schedule(w, n, seed=seed)
    assert lpt.loads.max() <= rnd.loads.max() + 1e-9


def test_initial_loads_respected():
    # Rail 0 pre-charged: flows avoid it (straggler mitigation hook).
    res = lpt_schedule(np.ones(4), 2, initial_loads=np.array([100.0, 0.0]))
    assert (res.assignment == 1).all()


# -- fast path ≡ reference ≡ device parity (heap / closed-form / jax) --------


def _assert_parity(w, n, src=None, init=None):
    fast = lpt_schedule(w, n, source_ids=src, initial_loads=init)
    ref = lpt_schedule_reference(w, n, source_ids=src, initial_loads=init)
    np.testing.assert_array_equal(fast.assignment, ref.assignment)
    # Bit-identical, not just close: the fast path replays the reference's
    # accumulation arithmetic exactly.
    np.testing.assert_array_equal(fast.loads, ref.loads)
    np.testing.assert_array_equal(fast.order, ref.order)


@settings(max_examples=100, deadline=None)
@given(
    weights=st.lists(st.floats(0.01, 1e3), min_size=1, max_size=200),
    n=st.integers(1, 16),
)
def test_fast_matches_reference_general(weights, n):
    _assert_parity(np.asarray(weights), n)


@settings(max_examples=100, deadline=None)
@given(
    weights=st.lists(st.integers(1, 5), min_size=1, max_size=200),
    n=st.integers(2, 8),
    src_hi=st.integers(1, 8),
)
def test_fast_matches_reference_tie_cases(weights, n, src_hi):
    """Small-integer weights force weight ties; random source ids force
    tie-breaking through the secondary sort key."""
    w = np.asarray(weights, dtype=float)
    rng = np.random.default_rng(w.size * 31 + n)
    src = rng.integers(0, src_hi, size=w.size)
    _assert_parity(w, n, src=src)
    # Equal-weight runs over a uniform LoadState take the closed-form path.
    _assert_parity(np.full(w.size, 3.0), n)
    _assert_parity(np.full(w.size, 3.0), n, init=np.full(n, 1.5))


@settings(max_examples=50, deadline=None)
@given(
    weights=st.lists(st.floats(0.01, 100.0), min_size=1, max_size=100),
    n=st.integers(2, 8),
)
def test_fast_matches_reference_initial_loads(weights, n):
    w = np.asarray(weights)
    rng = np.random.default_rng(w.size * 17 + n)
    _assert_parity(w, n, init=rng.uniform(0.0, 50.0, n))


@settings(max_examples=50, deadline=None)
@given(
    weights=st.lists(st.floats(0.5, 100.0), min_size=1, max_size=64),
    n=st.integers(2, 8),
)
def test_jax_matches_host_property(weights, n):
    w = np.asarray(weights)
    host = lpt_schedule(w, n)
    a, loads, _ = lpt_schedule_jax(jnp.asarray(w, jnp.float32), n)
    # f32 rounding can reorder near-equal weights; require agreement on
    # the induced loads rather than bitwise assignment equality.
    got = np.zeros(n)
    np.add.at(got, np.asarray(a), w)
    np.testing.assert_allclose(np.sort(got), np.sort(host.loads), rtol=1e-4)


def test_jax_jits_both_paths():
    import functools
    import jax

    w = jnp.asarray(np.full(32, 2.0), jnp.float32)
    for uniform in (False, True):
        fn = jax.jit(
            functools.partial(lpt_schedule_jax, assume_uniform=uniform),
            static_argnames=("num_rails",),
        )
        a, loads, mse = fn(w, num_rails=4)
        host = lpt_schedule(np.full(32, 2.0), 4)
        np.testing.assert_array_equal(np.asarray(a), host.assignment)
        np.testing.assert_allclose(np.asarray(loads), host.loads, rtol=1e-5)


def test_jax_uniform_fast_path_matches_host():
    for n in (2, 4, 8):
        for f in (1, 7, 64, 65):
            w = np.full(f, 2.0)
            host = lpt_schedule(w, n)
            a, loads, mse = lpt_schedule_jax(
                jnp.asarray(w, jnp.float32), n, assume_uniform=True
            )
            np.testing.assert_array_equal(np.asarray(a), host.assignment)
            np.testing.assert_allclose(np.asarray(loads), host.loads, rtol=1e-5)


# -- rail_mask: survivor-masked device scheduling ----------------------------


@settings(max_examples=50, deadline=None)
@given(
    weights=st.lists(st.integers(1, 1000), min_size=1, max_size=64),
    n=st.integers(2, 8),
    mask_seed=st.integers(0, 100),
)
def test_jax_rail_mask_matches_host(weights, n, mask_seed):
    """Three-way masked parity: the jax scan path agrees with the host
    fast path and the reference on which rails receive flows, and places
    nothing on dead rails. Assignments compare exactly — integer-valued
    weights are exactly representable in f32, so the device sort order
    can't diverge from the host's f64 order and argmin ties break toward
    the lowest alive index on both paths; loads at f32 tolerance."""
    w = np.asarray(weights, dtype=float)
    rng = np.random.default_rng(mask_seed)
    mask = rng.random(n) < 0.7
    if not mask.any():
        mask[int(rng.integers(n))] = True
    host = lpt_schedule(w, n, rail_mask=mask)
    ref = lpt_schedule_reference(w, n, rail_mask=mask)
    np.testing.assert_array_equal(host.assignment, ref.assignment)
    a, loads, _ = lpt_schedule_jax(jnp.asarray(w, jnp.float32), n, rail_mask=mask)
    np.testing.assert_array_equal(np.asarray(a), host.assignment)
    np.testing.assert_allclose(np.asarray(loads), host.loads, rtol=1e-5)
    assert mask[np.asarray(a)].all()  # no flow landed on a dead rail


def test_jax_rail_mask_uniform_path_matches_host():
    for n in (2, 4, 8):
        mask = np.ones(n, dtype=bool)
        mask[n // 2] = False
        for f in (1, 7, 64, 65):
            w = np.full(f, 2.0)
            host = lpt_schedule(w, n, rail_mask=mask)
            a, loads, _ = lpt_schedule_jax(
                jnp.asarray(w, jnp.float32), n, assume_uniform=True,
                rail_mask=mask,
            )
            np.testing.assert_array_equal(np.asarray(a), host.assignment)
            np.testing.assert_allclose(np.asarray(loads), host.loads, rtol=1e-5)


def test_jax_rail_mask_jits_with_traced_mask():
    import functools
    import jax

    fn = jax.jit(
        functools.partial(lpt_schedule_jax),
        static_argnames=("num_rails",),
    )
    w = jnp.asarray(np.full(16, 2.0), jnp.float32)
    mask = jnp.asarray([True, False, True, True])
    a, loads, _ = fn(w, num_rails=4, rail_mask=mask)
    host = lpt_schedule(np.full(16, 2.0), 4, rail_mask=np.asarray(mask))
    np.testing.assert_array_equal(np.asarray(a), host.assignment)
    assert float(loads[1]) == 0.0  # dead rail untouched


def test_jax_rail_mask_rejects_all_dead_and_bad_shape():
    w = jnp.asarray(np.ones(4), jnp.float32)
    with pytest.raises(ValueError):
        lpt_schedule_jax(w, 4, rail_mask=np.zeros(4, dtype=bool))
    with pytest.raises(ValueError):
        lpt_schedule_jax(w, 4, rail_mask=np.ones(3, dtype=bool))


# -- LptState: incremental windowed assignment -------------------------------


def test_lpt_state_single_window_matches_offline():
    rng = np.random.default_rng(4)
    w = rng.exponential(1.0, 300)
    src = rng.integers(0, 8, size=300)
    state = LptState(8)
    res = state.assign(w, source_ids=src)
    ref = lpt_schedule_reference(w, 8, source_ids=src)
    np.testing.assert_array_equal(res.assignment, ref.assignment)
    np.testing.assert_array_equal(state.loads, ref.loads)


def test_lpt_state_windows_match_sequential_reference():
    rng = np.random.default_rng(5)
    w = rng.exponential(1.0, 200)
    state = LptState(4, initial_loads=np.arange(4.0))
    loads = np.arange(4.0)
    for lo in range(0, 200, 33):
        chunk = w[lo:lo + 33]
        got = state.assign(chunk)
        want = lpt_schedule_reference(chunk, 4, initial_loads=loads)
        loads = want.loads
        np.testing.assert_array_equal(got.assignment, want.assignment)
        np.testing.assert_array_equal(state.loads, want.loads)


def test_lpt_state_extra_loads_bias_without_leak():
    # Pre-charge steers the assignment but never enters the realized loads.
    state = LptState(2)
    res = state.assign(np.ones(3), extra_loads=np.array([100.0, 0.0]))
    assert (res.assignment == 1).all()
    np.testing.assert_allclose(state.loads, [0.0, 3.0])
