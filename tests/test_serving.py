"""Serving path (`repro.serve`) — release-relative CCT + tail latency.

Anchors:

1. **Release-relative semantics** — streaming flow CCT is sojourn time
   (finish − release) on both backends: t=0 streaming bit-matches the
   one-shot collective, and shifting a round's release shifts its sojourn
   by ~0 (fp tolerance of the shifted arithmetic).
2. **Quantile labels** — p99.9 no longer collides with p99.
3. **Goodput BusBw** — retransmissions inflate wire volume, not achieved
   bandwidth.
4. **Serving metrics** — TTFT / per-token latency on hand-computed micro
   cases; whole-workload time shifts leave every metric bit-identical.
5. **Seeded regression** — `rails-online`+feedback beats PLB/REPS on p99
   TTFT under the PR-4 degraded-fabric grid.
"""

import numpy as np
import pytest

from repro.core.traffic import (
    ServeWorkload,
    request_arrival_times,
    serve_workload,
    uniform_workload,
)
from repro.netsim import (
    FaultSpec,
    LossConfig,
    run_collective,
    run_streaming_collective,
    step_profile,
)
from repro.netsim.events import cct_percentile_dict, quantile_label
from repro.sched import run_pipeline
from repro.sched.serving import (
    RequestMetrics,
    expert_counts_to_matrix,
    run_serving,
    simulate_decode_trace,
)

M, N = 4, 4
B = 8 * 2**20
CHUNK = 1 * 2**20


# -- quantile labels (p99.9 vs p99 collision) --------------------------------


def test_quantile_labels_keep_fractions():
    assert quantile_label(50.0) == "p50"
    assert quantile_label(99.0) == "p99"
    assert quantile_label(99.9) == "p99.9"


def test_percentile_dict_p999_distinct_from_p99():
    # 1000 values 1..1000: p99 and p99.9 are genuinely different numbers.
    vals = np.arange(1.0, 1001.0)
    d = cct_percentile_dict(vals, qs=(99.0, 99.9))
    assert "p99" in d and "p99.9" in d
    assert d["p99.9"] > d["p99"]
    np.testing.assert_allclose(d["p99"], np.percentile(vals, 99.0))
    np.testing.assert_allclose(d["p99.9"], np.percentile(vals, 99.9))


def test_percentile_dict_empty_branch_has_fractional_keys():
    d = cct_percentile_dict([], qs=(99.0, 99.9))
    assert d == {"mean": 0.0, "p99": 0.0, "p99.9": 0.0, "max": 0.0}


def test_default_cct_dict_includes_p999():
    tm = uniform_workload(M, N, bytes_per_pair=B)
    m = run_collective(tm, "rails", chunk_bytes=CHUNK)
    assert "p99.9" in m.cct
    assert m.cct["p99.9"] >= m.cct["p99"]
    assert "cct_p99.9_s" in m.row()


# -- release-relative CCT (sojourn semantics) --------------------------------


@pytest.mark.parametrize("backend", ["event", "vector"])
def test_streaming_t0_flow_cct_matches_oneshot(backend):
    """At t=0 sojourn == absolute finish bit for bit, on both backends."""
    tm = uniform_workload(M, N, bytes_per_pair=B)
    off = run_collective(tm, "rails", chunk_bytes=CHUNK, backend=backend)
    st = run_streaming_collective(tm, "rails", chunk_bytes=CHUNK, backend=backend)
    assert st.metrics.cct == off.cct
    assert st.metrics.makespan == off.makespan


@pytest.mark.parametrize("backend", ["event", "vector"])
def test_shifted_release_leaves_sojourn_unchanged(backend):
    """One round released at Δ: every flow's sojourn equals the t=0 run's
    (the whole simulation translates; fp tolerance covers the Δ-shifted
    arithmetic)."""
    tm = uniform_workload(M, N, bytes_per_pair=B)
    base = run_streaming_collective(tm, "rails", chunk_bytes=CHUNK, backend=backend)
    delta = 0.125
    shifted = run_streaming_collective(
        [(delta, tm)], "rails", chunk_bytes=CHUNK, backend=backend
    )
    f0 = base.sim.flow_cct
    f1 = shifted.sim.flow_cct
    assert set(f0) == set(f1)
    np.testing.assert_allclose(
        [f1[k] for k in sorted(f0)], [f0[k] for k in sorted(f0)], rtol=1e-9
    )
    # absolute completion still reflects the shift...
    assert shifted.metrics.makespan == pytest.approx(base.metrics.makespan + delta)
    # ...but the reported CCT percentiles don't.
    for k, v in base.metrics.cct.items():
        assert f1 and shifted.metrics.cct[k] == pytest.approx(v, rel=1e-9), k


def test_streaming_sojourn_excludes_release_wait():
    """A round released late must not report its wait-before-release as
    CCT: two identical rounds far apart report near-identical sojourns."""
    tm = uniform_workload(M, N, bytes_per_pair=B / 4)
    gap = 1.0  # far beyond each round's drain time
    res = run_streaming_collective(
        [(0.0, tm), (gap, tm)], "rails", chunk_bytes=CHUNK
    )
    soj = res.round_sojourn
    assert soj[1] == pytest.approx(soj[0], rel=1e-9)
    assert soj[1] < gap / 100  # nowhere near the absolute finish (~gap)
    # round_cct stays absolute
    assert res.round_cct[1] > gap


@pytest.mark.parametrize("backend", ["event", "vector"])
def test_round_sojourn_times_match_manual(backend):
    tm = uniform_workload(M, N, bytes_per_pair=B / 4)
    releases = [0.0, 2e-4, 7e-4]
    res = run_streaming_collective(
        [(t, tm) for t in releases], "rails", chunk_bytes=CHUNK, backend=backend
    )
    for rnd, cct in res.round_cct.items():
        assert res.round_sojourn[rnd] == cct - releases[rnd]


def test_pipeline_round_latency_uses_engine_sojourn():
    from repro.core.traffic import microbatch_stream

    tms = microbatch_stream(M, N, 3, bytes_per_pair=B / 3, seed=9)
    res = run_pipeline(tms, gap_fraction=0.5, chunk_bytes=CHUNK)
    for rnd, cct in res.round_cct.items():
        assert res.round_latency[rnd] == cct - res.releases[rnd]
        assert res.round_latency[rnd] > 0


def test_event_vector_sojourn_parity_on_stream():
    tm = uniform_workload(M, N, bytes_per_pair=B / 2)
    stream = [(0.0, tm), (3e-4, tm)]
    e = run_streaming_collective(stream, "rails", chunk_bytes=CHUNK, backend="event")
    v = run_streaming_collective(stream, "rails", chunk_bytes=CHUNK, backend="vector")
    assert e.sim.flow_cct == v.sim.flow_cct
    assert e.round_sojourn == v.round_sojourn


# -- goodput vs wire BusBw ----------------------------------------------------


def test_static_run_goodput_equals_wire():
    tm = uniform_workload(M, N, bytes_per_pair=B)
    m = run_collective(tm, "rails", chunk_bytes=CHUNK)
    assert m.goodput_bytes == m.wire_bytes == pytest.approx(tm.total_bytes())
    assert m.bus_bw == m.wire_bus_bw > 0


def test_lossy_run_reports_goodput_busbw_below_wire():
    tm = uniform_workload(M, N, bytes_per_pair=B)
    spec = FaultSpec(
        loss=LossConfig(rate=0.02, rto=5e-4, bad_rate=0.3,
                        p_enter_bad=0.02, p_leave_bad=0.3),
        seed=7,
    )
    m = run_collective(tm, "rails", chunk_bytes=CHUNK, fault_spec=spec)
    # retransmissions actually fired, inflating the wire volume...
    assert m.wire_bytes > m.goodput_bytes
    # ...goodput is exactly the unique payload bytes,
    assert m.goodput_bytes == pytest.approx(tm.total_bytes())
    # and "achieved" BusBw is goodput-based, below the raw wire rate.
    assert m.bus_bw < m.wire_bus_bw
    assert m.bus_bw == pytest.approx(m.goodput_bytes / m.makespan)
    assert m.wire_bus_bw == pytest.approx(m.wire_bytes / m.makespan)


# -- serving workload generation ---------------------------------------------


@pytest.mark.parametrize("process", ["poisson", "bursty", "diurnal"])
def test_arrival_processes_start_at_zero_and_are_sorted(process):
    t = request_arrival_times(64, 1e-3, process, seed=3)
    assert t.shape == (64,)
    assert t[0] == 0.0
    assert np.all(np.diff(t) >= 0)
    assert np.isfinite(t).all()


def test_arrival_process_rejects_unknown():
    with pytest.raises(ValueError, match="poisson|bursty|diurnal"):
        request_arrival_times(4, 1e-3, "weekly")


def test_serve_workload_structure():
    wl = serve_workload(
        M, N, num_requests=6, mean_gap=1e-3, prefill_tokens=32,
        decode_rounds=3, decode_tokens=4, decode_gap=1e-4, seed=5,
    )
    assert len(wl.requests) == 6
    assert len(wl.rounds) == 6 * (1 + 3)
    # rounds sorted by release (streaming round_id == list index)
    rel = [r.release for r in wl.rounds]
    assert rel == sorted(rel)
    for req in wl.requests:
        mine = [r for r in wl.rounds if r.req_id == req.req_id]
        pre = [r for r in mine if r.kind == "prefill"]
        dec = sorted((r for r in mine if r.kind == "decode"), key=lambda r: r.step)
        assert len(pre) == 1 and pre[0].release == req.arrival
        assert [r.step for r in dec] == [1, 2, 3]
        for r in dec:  # decode cadence off the arrival
            assert r.release == pytest.approx(req.arrival + r.step * 1e-4)
        for r in mine:  # traffic leaves only from the home domain
            sends = r.tm.d2.sum(axis=1)
            assert sends[req.home_domain] == r.tm.d2.sum()
            r.tm.validate()


# -- TTFT / per-token metrics -------------------------------------------------


def test_request_metrics_hand_computed_percentiles():
    ttft = np.arange(1.0, 1001.0)  # 1..1000
    rm = RequestMetrics(ttft=ttft, token_latency=np.array([2.0, 4.0]),
                        sojourn=ttft + 1.0)
    p = rm.ttft_percentiles()
    np.testing.assert_allclose(p["p50"], np.percentile(ttft, 50.0))
    np.testing.assert_allclose(p["p99"], np.percentile(ttft, 99.0))
    np.testing.assert_allclose(p["p99.9"], np.percentile(ttft, 99.9))
    assert p["p99.9"] > p["p99"]
    assert rm.token_percentiles()["max"] == 4.0
    s = rm.summary()
    assert set(s) == {"ttft", "token_latency", "sojourn"}


def test_run_serving_single_request_ttft_matches_round_completion():
    """One request: TTFT is exactly the prefill round's completion (arrival
    is the time origin), per-token latency each decode round's sojourn."""
    wl = serve_workload(
        M, N, num_requests=1, mean_gap=1e-3, prefill_tokens=64,
        decode_rounds=2, decode_tokens=4, decode_gap=1e-3, seed=2,
    )
    res = run_serving(wl, "rails")
    st = res.streaming
    assert res.request.ttft[0] == st.round_cct[0]  # arrival == t0 == 0
    for k in (1, 2):
        assert res.request.token_latency[k - 1] == pytest.approx(
            st.round_cct[k] - wl.rounds[k].release, abs=1e-12
        )
    assert res.request.sojourn[0] == pytest.approx(max(st.round_cct.values()))
    # decode rounds are far apart (1ms gap >> drain) -> TTFT < sojourn
    assert res.request.ttft[0] < res.request.sojourn[0]


@pytest.mark.parametrize("delta", [0.5, 7.25, 123.456])
def test_run_serving_shift_invariance_bit_exact(delta):
    """Shifting every arrival/release by Δ leaves every latency metric
    bit-identical (the driver normalizes to the earliest release on a 1 ns
    grid) — the acceptance property of the release-relative semantics."""
    wl = serve_workload(M, N, num_requests=8, mean_gap=3e-4, seed=4)
    a = run_serving(wl, "rails-online")
    b = run_serving(wl.shifted(delta), "rails-online")
    assert np.array_equal(a.request.ttft, b.request.ttft)
    assert np.array_equal(a.request.token_latency, b.request.token_latency)
    assert np.array_equal(a.request.sojourn, b.request.sojourn)
    assert a.request.summary() == b.request.summary()


def test_serve_workload_shifted_preserves_structure():
    wl = serve_workload(M, N, num_requests=3, mean_gap=1e-3, seed=6)
    sh = wl.shifted(2.0)
    assert isinstance(sh, ServeWorkload)
    assert [r.req_id for r in sh.rounds] == [r.req_id for r in wl.rounds]
    for a, b in zip(wl.rounds, sh.rounds):
        assert b.release == a.release + 2.0
        assert b.tm is a.tm  # traffic shared, not copied


# -- seeded regression: tails under the PR-4 fault grid -----------------------


def test_rails_online_feedback_beats_reactive_p99_ttft_under_faults():
    """The serving-path headline: on a degraded fabric (one rail at 0.25x
    + Gilbert-Elliott loss, the PR-4 grid's serving cell), proactive
    rails-online with EWMA health feedback holds a lower p99 TTFT than the
    reactive PLB/REPS baselines. Seeded end to end."""
    wl = serve_workload(
        M, N, num_requests=32, mean_gap=5e-4, prefill_tokens=1024,
        decode_rounds=2, decode_tokens=8, decode_gap=1e-4,
        bytes_per_token=16 * 2**10, seed=12,
    )
    spec = FaultSpec(
        rail_profiles={N - 1: step_profile(0.0, 0.25)},
        loss=LossConfig(rate=0.01, rto=1e-4, bad_rate=0.3,
                        p_enter_bad=0.02, p_leave_bad=0.3),
        seed=11,
    )

    def p99(pol, fb):
        res = run_serving(
            wl, pol, chunk_bytes=256 * 2**10, fault_spec=spec, feedback=fb
        )
        assert (res.streaming.sim.dynamics or {}).get("drops", 0) > 0
        return res.request.ttft_percentiles()["p99"]

    rails = p99("rails-online", True)
    plb = p99("plb", False)
    reps = p99("reps", False)
    assert rails < plb
    assert rails < reps


# -- decode-trace replay (launch/serve.py --sim-fabric) -----------------------


def test_expert_counts_to_matrix_convention():
    counts = np.array([10.0, 0.0, 6.0, 0.0, 2.0])  # 5 experts, M=4 domains
    c2 = expert_counts_to_matrix(counts, 4)
    assert c2.shape == (4, 4)
    np.testing.assert_allclose(np.diag(c2), 0.0)
    # experts 0 and 4 live on domain 0 (round-robin): 12 tokens ingress,
    # expert 2 puts 6 on domain 2; uniform senders split each column evenly.
    np.testing.assert_allclose(c2[:, 0], [0.0, 4.0, 4.0, 4.0])
    np.testing.assert_allclose(c2[:, 2], [2.0, 2.0, 0.0, 2.0])
    assert c2.sum() == pytest.approx(18.0)


def test_simulate_decode_trace_latencies_and_shift_invariance():
    rng = np.random.default_rng(0)
    counts = [rng.integers(1, 40, 8) for _ in range(12)]
    releases = np.arange(12) * 1.5e-3
    a = simulate_decode_trace(counts, releases, M, N, bytes_per_token=16 * 2**10)
    assert a.token_latency.shape == (12,)
    assert np.all(a.token_latency > 0)
    assert "p99.9" in a.summary()
    # arbitrary time origin (a real wall-clock trace) changes nothing
    b = simulate_decode_trace(counts, releases + 1.7e9, M, N,
                              bytes_per_token=16 * 2**10)
    assert np.array_equal(a.token_latency, b.token_latency)


def test_decode_fn_returns_real_gating_counts():
    """The --sim-fabric source: a reduced MoE arch's decode step surfaces
    per-expert routed-token counts (batch * top_k per layer, summed)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import decode_fn, init_cache, init_params

    cfg = get_config("mixtral-8x7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, 2, 8)
    tok = jnp.ones((2, 1), jnp.int32)
    logits, cache2, counts = jax.jit(
        lambda p, c, t: decode_fn(p, cfg, c, t, 0, return_counts=True)
    )(params, cache, tok)
    assert logits.shape == (2, cfg.vocab_size)
    counts = np.asarray(counts)
    assert counts.shape == (cfg.num_experts,)
    # every token routes to top_k experts in every layer
    assert counts.sum() == 2 * cfg.experts_per_token * cfg.num_layers
    # parity with the counts-free path
    logits2, _ = jax.jit(lambda p, c, t: decode_fn(p, cfg, c, t, 0))(
        params, init_cache(cfg, 2, 8), tok
    )
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2),
                               rtol=1e-5, atol=1e-5)
