"""Vector-backend parity — the prefix-scan simulator must match the DES.

The vector backend (`repro.netsim.fastsim`) recomputes the event engine's
FIFO dynamics with array scans. Golden anchor: on every policy and every
paper workload the CCT statistics must match the engine within fp
tolerance, and runs whose ties the tie-key model covers exactly (rail-path
planners, uniform chunk waves) must match *bit for bit*. The scan itself
is cross-checked against the wavefront oracle on randomized and
equality-heavy inputs, and the struct-of-arrays builders against the
scalar splitter they replaced.
"""

import numpy as np
import pytest

from repro.core.plan import split_message, split_sizes_vector
from repro.core.theorems import theorem2_optimal_time
from repro.core.traffic import (
    bursty_release_times,
    microbatch_stream,
    mixtral_trace_workload,
    receiver_skew_workload,
    sender_skew_workload,
    sparse_topk_workload,
    uniform_workload,
)
from repro.netsim import (
    ChunkJob,
    Engine,
    LinkIndex,
    build_job_arrays,
    build_jobs,
    run_collective,
    run_streaming_collective,
)
from repro.netsim.balancers import Policy
from repro.netsim.fastsim import (
    ArraySimResult,
    _scan_busy_periods,
    _scan_wavefront,
    entry_order_rank,
    paths_from_jobs,
    simulate_chunk_arrays,
)
from repro.netsim.topology import RailTopology

M, N = 4, 4
B = 8 * 2**20
CHUNK = 1 * 2**20

ALL_POLICIES = ("ecmp", "plb", "minrtt", "reps", "rails")


def _workloads():
    return {
        "uniform": uniform_workload(M, N, bytes_per_pair=B),
        "sparse04": sparse_topk_workload(M, N, sparsity=0.4, bytes_per_pair=B, seed=1),
        "sender_skew": sender_skew_workload(M, N, total_bytes=B * 16, seed=1),
        "recv_skew": receiver_skew_workload(M, N, total_bytes=B * 16, seed=1),
        "mixtral_sparse": mixtral_trace_workload(
            M, N, phase="stable", mode="sparse", seed=2
        ),
    }


# -- golden backend parity ----------------------------------------------------


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_vector_matches_event_all_policies(policy):
    """Vector CCT == event CCT within fp tolerance, every policy/workload.

    Covers both path families: rails/minrtt take 2-link rail paths, the
    others mix 2-link (same-rail) and 4-link spine paths. Makespan is
    pinned at fp tolerance everywhere, and rail-path policies are pinned
    bit-exact below. Spine policies' CCT stats get 2e-3: equal-size chunks
    at t=0 make event times massively degenerate, and on 4-hop cascades
    the engine's global sequence counter can order a handful of
    exactly-simultaneous service grants differently than the vector tie
    model — a different choice among equally valid FIFO schedules that
    shifts a few flows by one service quantum. With non-degenerate inputs
    (randomized sizes/releases below) parity is 1e-12 on every path shape.
    """
    for name, tm in _workloads().items():
        e = run_collective(tm, policy, chunk_bytes=CHUNK, seed=3, backend="event")
        v = run_collective(tm, policy, chunk_bytes=CHUNK, seed=3, backend="vector")
        assert np.isclose(v.makespan, e.makespan, rtol=1e-9), (policy, name)
        cct_rtol = 1e-9 if policy in ("rails", "minrtt") else 2e-3
        for key, val in e.cct.items():
            assert np.isclose(v.cct[key], val, rtol=cct_rtol, atol=1e-15), (
                policy, name, key,
            )
        np.testing.assert_allclose(v.nic_tx, e.nic_tx, rtol=1e-9)
        np.testing.assert_allclose(v.nic_rx, e.nic_rx, rtol=1e-9)
        assert np.isclose(v.send_mse, e.send_mse, rtol=1e-6, atol=1e-12)
        assert np.isclose(v.recv_mse, e.recv_mse, rtol=1e-6, atol=1e-12)


@pytest.mark.parametrize("policy", ("rails", "minrtt"))
def test_vector_bit_exact_rail_paths(policy):
    """Rail-path policies (2-link paths, uniform chunk waves): bit-exact."""
    for name, tm in _workloads().items():
        e = run_collective(tm, policy, chunk_bytes=CHUNK, seed=3, backend="event")
        v = run_collective(tm, policy, chunk_bytes=CHUNK, seed=3, backend="vector")
        assert v.makespan == e.makespan, (policy, name)
        assert v.cct == e.cct, (policy, name)


def test_vector_bit_exact_uniform_rails():
    """The uniform one-shot collective — every wave ties — is bit-exact."""
    tm = uniform_workload(M, N, bytes_per_pair=B)
    e = run_collective(tm, "rails", chunk_bytes=CHUNK, backend="event")
    v = run_collective(tm, "rails", chunk_bytes=CHUNK, backend="vector")
    assert v.makespan == e.makespan
    assert v.cct == e.cct


def test_coalesce_defaults_to_event_backend():
    """Flowlet coalescing is an event-engine approximation: it resolves to
    the event backend by default, and explicitly asking for the vector
    backend alongside it is an error (as in run_streaming_collective)."""
    tm = uniform_workload(2, 2, bytes_per_pair=CHUNK)
    merged = run_collective(tm, "rails", chunk_bytes=CHUNK, coalesce=True)
    exact = run_collective(tm, "rails", chunk_bytes=CHUNK, backend="event")
    assert merged.makespan == exact.makespan  # single-chunk lanes: no merge
    with pytest.raises(ValueError, match="coalesc"):
        run_collective(
            tm, "rails", chunk_bytes=CHUNK, coalesce=True, backend="vector"
        )


def test_unknown_backend_rejected():
    tm = uniform_workload(2, 2, bytes_per_pair=CHUNK)
    with pytest.raises(ValueError, match="backend"):
        run_collective(tm, "rails", chunk_bytes=CHUNK, backend="gpu")


@pytest.mark.parametrize("policy", ("rails", "minrtt"))
def test_vector_bit_exact_with_constant_fault_spec(policy):
    """Constant-profile fault specs fold into static rates: the vector
    backend accepts them and stays bit-exact with the event engine."""
    from repro.netsim import FaultSpec

    spec = FaultSpec(rail_profiles={0: 1.0, 1: 0.5})
    tm = uniform_workload(M, N, bytes_per_pair=B)
    e = run_collective(
        tm, policy, chunk_bytes=CHUNK, seed=3, backend="event", fault_spec=spec
    )
    v = run_collective(
        tm, policy, chunk_bytes=CHUNK, seed=3, backend="vector", fault_spec=spec
    )
    assert v.makespan == e.makespan
    assert v.cct == e.cct
    # And the degraded rail actually bites: slower than the clean fabric.
    clean = run_collective(tm, policy, chunk_bytes=CHUNK, seed=3, backend="vector")
    assert v.makespan > clean.makespan


def test_vector_rejects_dynamic_fault_spec_naming_fallback():
    """Any non-constant LinkModel on the vector backend is a clear error
    that names the event fallback."""
    from repro.netsim import FaultSpec, step_profile

    tm = uniform_workload(2, 2, bytes_per_pair=CHUNK)
    spec = FaultSpec(rail_profiles={0: step_profile(1e-3, 0.5)})
    with pytest.raises(ValueError, match="backend='event'"):
        run_collective(tm, "rails", chunk_bytes=CHUNK, backend="vector", fault_spec=spec)
    with pytest.raises(ValueError, match="backend='event'"):
        run_streaming_collective(
            tm, "rails", chunk_bytes=CHUNK, backend="vector", fault_spec=spec
        )
    with pytest.raises(ValueError, match="backend='event'"):
        LinkIndex(RailTopology(2, 2, fault_spec=spec))


# -- randomized release times (direct harness) --------------------------------


class _FixedPathPolicy(Policy):
    """Deterministic per-chunk path table — isolates the simulators."""

    name = "fixed"

    def __init__(self, topo, paths):
        super().__init__(topo)
        self._paths = paths

    def choose_path(self, eng, job):
        return self._paths[job.chunk_id]


def _random_jobs(topo, rng, num_chunks, spine_fraction=0.0, max_release=1e-3):
    """Random sizes/releases + a fixed random path per chunk."""
    jobs: dict = {}
    paths = {}
    for cid in range(num_chunks):
        d = int(rng.integers(topo.m))
        g = int(rng.integers(topo.n))
        fdom = int((d + 1 + rng.integers(topo.m - 1)) % topo.m)
        gd = int(rng.integers(topo.n))
        if rng.random() < spine_fraction and g != gd:
            paths[cid] = topo.spine_path(d, fdom, g, gd, int(rng.integers(topo.num_spines)))
        else:
            paths[cid] = topo.rail_path(d, fdom, int(rng.integers(topo.n)))
        jobs.setdefault((d, g), []).append(
            ChunkJob(
                chunk_id=cid,
                flow_id=cid,
                src_domain=d,
                src_gpu=g,
                dst_domain=fdom,
                dst_gpu=gd,
                size=float(rng.uniform(0.5, 2.0) * CHUNK),
                arrival_time=float(rng.uniform(0.0, max_release)),
            )
        )
    return jobs, paths


@pytest.mark.parametrize("spine_fraction", [0.0, 0.5])
def test_randomized_releases_match_engine(spine_fraction):
    """Random sizes + random release times, rail and spine paths mixed:
    per-chunk finish times match the event engine."""
    topo = RailTopology(3, 3)
    index = LinkIndex(topo)
    for seed in (11, 12, 13):
        # Two identical job sets: the engine mutates jobs in place.
        jobs, paths = _random_jobs(
            topo, np.random.default_rng(seed), 200, spine_fraction
        )
        jobs2, paths2 = _random_jobs(
            topo, np.random.default_rng(seed), 200, spine_fraction
        )
        res_e = Engine(topo).run(jobs, _FixedPathPolicy(topo, paths))
        finish_e = np.zeros(200)
        for js in jobs.values():
            for j in js:
                finish_e[j.chunk_id] = j.finish_time
        ordered = _FixedPathPolicy(topo, paths2).assign_batch(
            Engine(topo), jobs2, now=0.0
        )
        lbl, rank = paths_from_jobs(ordered, index, 200)
        size = np.zeros(200)
        release = np.zeros(200)
        for js in jobs2.values():
            for j in js:
                size[j.chunk_id] = j.size
                release[j.chunk_id] = j.arrival_time
        res_v = simulate_chunk_arrays(index, lbl, size, release, rank)
        np.testing.assert_allclose(res_v.finish, finish_e, rtol=1e-12)
        assert np.isclose(res_v.makespan, res_e.makespan, rtol=1e-12)
        for link, volume in res_e.link_bytes.items():
            assert np.isclose(res_v.link_bytes[link], volume, rtol=1e-9)


# -- scan oracle cross-check --------------------------------------------------


def _random_scan_case(rng, f, num_links, tie_pace):
    link = rng.integers(0, num_links, f).astype(np.int16)
    if tie_pace:
        # equality-heavy: arrivals drawn from a tiny grid so many arrivals
        # tie each other and the resulting completions
        arrival = rng.integers(0, 4, f) * 1e-4
        service = np.full(f, 1e-4)
    else:
        arrival = rng.uniform(0, 1e-3, f)
        service = rng.uniform(1e-6, 1e-4, f)
    ties = (
        np.zeros(f, dtype=np.int64),
        np.zeros(f, dtype=np.int64),
        rng.permutation(f).astype(np.int64),
    )
    return link, arrival, service, ties


@pytest.mark.parametrize("tie_pace", [False, True])
def test_busy_period_scan_matches_wavefront_oracle(tie_pace):
    """The production scan (busy-period decomposition + repair) must equal
    the wavefront oracle bit for bit on random and equality-heavy inputs."""
    rng = np.random.default_rng(5)
    for _ in range(5):
        link, arrival, service, ties = _random_scan_case(rng, 400, 7, tie_pace)
        out1 = _scan_busy_periods(link, arrival, ties, service, True)
        out2 = _scan_wavefront(link, arrival, ties, service, True)
        for got, want in zip(out1, out2):
            np.testing.assert_array_equal(got, want)


def test_constant_release_partial_level_tie_ranks():
    """Regression: at partial levels (l2s/s2l) the constant-release sort
    key carries opener ranks from the previous level's *global* rank space,
    which can exceed the level's job count — the composite key must scale
    by the actual rank span or different links' queues interleave and
    queued chunks get served in parallel."""
    from repro.netsim.fastsim import _scan_constant_release

    link = np.array([0, 0, 1], dtype=np.int16)
    tie_c = np.array([0, 5, 1], dtype=np.int64)  # rank 5 >= f == 3
    service = np.ones(3)
    comp, start, _na, _nb, _nc = _scan_constant_release(
        link, tie_c, service, 0.0, True, False
    )
    np.testing.assert_array_equal(comp, [1.0, 2.0, 1.0])
    np.testing.assert_array_equal(start, [0.0, 1.0, 0.0])
    # End-to-end shape that reaches this path: one equal-size message per
    # sender, so every cross chunk hits its l2s link simultaneously.
    tm = uniform_workload(4, 4, bytes_per_pair=4096.0)
    e = run_collective(tm, "ecmp", chunk_bytes=8192.0, seed=3, backend="event")
    v = run_collective(tm, "ecmp", chunk_bytes=8192.0, seed=3, backend="vector")
    assert np.isclose(v.makespan, e.makespan, rtol=1e-9)


# -- struct-of-arrays builders ------------------------------------------------


def _build_jobs_reference(tm, chunk_bytes):
    """The pre-vectorization build_jobs loop, kept as the parity oracle."""
    jobs, chunk_id, flow_id = {}, 0, 0
    m, n = tm.num_domains, tm.num_rails
    for d in range(m):
        for g in range(n):
            sender_jobs = []
            for f in range(m):
                if f == d:
                    continue
                for gd in range(n):
                    size = float(tm.d1[d, g, f, gd])
                    if size <= 0:
                        continue
                    for part in split_message(size, chunk_bytes, d, f, g, flow_id):
                        sender_jobs.append(
                            ChunkJob(
                                chunk_id=chunk_id, flow_id=flow_id,
                                src_domain=d, src_gpu=g,
                                dst_domain=f, dst_gpu=gd, size=part.size,
                            )
                        )
                        chunk_id += 1
                    flow_id += 1
            if sender_jobs:
                jobs[(d, g)] = sender_jobs
    return jobs


def test_build_jobs_matches_reference_loop():
    for tm in (
        uniform_workload(3, 2, bytes_per_pair=2.5 * CHUNK),
        sparse_topk_workload(M, N, sparsity=0.4, bytes_per_pair=B, seed=4),
    ):
        got = build_jobs(tm, CHUNK)
        ref = _build_jobs_reference(tm, CHUNK)
        assert list(got) == list(ref)
        for key in ref:
            for a, b in zip(got[key], ref[key]):
                assert (a.chunk_id, a.flow_id, a.src_domain, a.src_gpu,
                        a.dst_domain, a.dst_gpu, a.size) == (
                    b.chunk_id, b.flow_id, b.src_domain, b.src_gpu,
                    b.dst_domain, b.dst_gpu, b.size,
                )


def test_split_sizes_vector_matches_split_message():
    rng = np.random.default_rng(9)
    sizes = np.concatenate([
        rng.uniform(0, 5 * CHUNK, 50),
        [0.0, CHUNK, 2.0 * CHUNK, CHUNK + 1e-13, 3 * CHUNK + 0.5],
    ])
    counts, flat = split_sizes_vector(sizes, CHUNK)
    off = 0
    for sz, cnt in zip(sizes, counts):
        ref = [p.size for p in split_message(float(sz), CHUNK, 0, 1)]
        assert len(ref) == cnt
        assert flat[off:off + cnt].tolist() == ref
        off += cnt
    assert off == flat.size
    with pytest.raises(ValueError):
        split_sizes_vector(sizes, 0.0)


def test_entry_order_matches_assign_batch():
    """entry_order_rank replicates Policy.assign_batch round-robin order."""
    tm = sparse_topk_workload(M, N, sparsity=0.4, bytes_per_pair=B, seed=1)
    jobs = build_jobs(tm, CHUNK)
    topo = RailTopology(M, N)
    ja = build_job_arrays(tm, CHUNK)

    class _Rail0(Policy):
        def choose_path(self, eng, job):
            return self.topo.rail_path(job.src_domain, job.dst_domain, 0)

    ordered = _Rail0(topo).assign_batch(Engine(topo), jobs, now=0.0)
    rank = entry_order_rank(ja.src_domain, ja.src_gpu, topo.n)
    for i, job in enumerate(ordered):
        assert rank[job.chunk_id] == i


# -- streaming vector backend -------------------------------------------------


def _stream(rounds=3, seed=1):
    tms = microbatch_stream(M, N, rounds, bytes_per_pair=B / rounds, seed=seed)
    gap = 0.5 * theorem2_optimal_time(tms[0].d2, N, 50e9)
    releases = bursty_release_times(rounds, gap, seed=seed + 1)
    return list(zip(releases, tms))


@pytest.mark.parametrize("window", [None, 4])
def test_streaming_vector_bitmatches_event(window):
    stream = _stream()
    e = run_streaming_collective(
        stream, "rails-online", chunk_bytes=CHUNK, window=window, backend="event"
    )
    v = run_streaming_collective(
        stream, "rails-online", chunk_bytes=CHUNK, window=window, backend="vector"
    )
    assert v.metrics.makespan == e.metrics.makespan
    assert v.metrics.cct == e.metrics.cct
    assert v.round_cct == e.round_cct


def test_streaming_vector_rejects_feedback_and_reactive():
    stream = _stream()
    with pytest.raises(ValueError, match="feedback-free"):
        run_streaming_collective(
            stream, "rails-online", chunk_bytes=CHUNK, feedback=True,
            backend="vector",
        )
    with pytest.raises(ValueError, match="proactive"):
        run_streaming_collective(
            stream, "minrtt", chunk_bytes=CHUNK, backend="vector"
        )


# -- result-object guards -----------------------------------------------------


def test_empty_collective_vector():
    zero = uniform_workload(2, 2, bytes_per_pair=B)
    zero.d1[:] = 0.0
    zero.d2[:] = 0.0
    m = run_collective(zero, "rails", chunk_bytes=CHUNK, backend="vector")
    assert m.makespan == 0.0
    assert m.cct["p99"] == 0.0 and m.cct["mean"] == 0.0


def test_array_simresult_surface():
    tm = uniform_workload(2, 2, bytes_per_pair=2 * CHUNK)
    topo = RailTopology(2, 2)
    index = LinkIndex(topo)
    ja = build_job_arrays(tm, CHUNK)
    from repro.netsim.balancers import RailSPolicy

    lbl = RailSPolicy(topo).plan_arrays(ja, index)
    rank = entry_order_rank(ja.src_domain, ja.src_gpu, topo.n)
    res = simulate_chunk_arrays(
        index, lbl, ja.size, ja.release, rank,
        flow_id=ja.flow_id, round_id=ja.round_id,
    )
    assert isinstance(res, ArraySimResult)
    assert res.makespan == res.finish.max()
    assert set(res.flow_cct) == set(ja.flow_id.tolist())
    assert res.round_completion_times() == {0: res.makespan}
    pcts = res.cct_percentiles()
    assert pcts["max"] == max(res.flow_cct.values())
