"""Hierarchical multi-pod fabrics — flat-pod equivalence, hier-LPT, FEC.

Four contracts:

1. **BitExact flat pod** — ``MultiPodFabric(num_pods=1)`` with FEC off is
   the degenerate fabric: makespans and CCT percentiles must be
   *bit-exact* equal to ``RailTopology`` on the event, vector, and device
   backends (the CI parity gate keys on the BitExact class names).
2. **BitExact multipod backends** — on a real multi-pod fabric the event
   engine and the vector scan must still agree exactly for the proactive
   planners (the same contract the flat fabric has always pinned).
3. **Hier-LPT** — the two-level schedule balances WAN lanes where the
   flat policy's static ``rail % wan_lanes`` spray cannot, beats it on
   MoE-gated traffic, and degrades to a no-op on dense-uniform traffic
   (Theorem 3's symmetry, one tier up).
4. **FEC** — seeded regression: XOR parity beats go-back-N when the WAN
   RTT makes retransmission expensive (10 ms RTT, 1% loss) and *loses*
   at zero loss, where its ``r/k`` redundancy is a pure bandwidth tax.
"""

import numpy as np
import pytest

from repro.core.lpt import hier_lpt_schedule
from repro.core.traffic import TrafficMatrix, sparse_topk_workload, uniform_workload
from repro.netsim import (
    FaultSpec,
    FecConfig,
    LinkIndex,
    LossConfig,
    MultiPodFabric,
    RailTopology,
    build_job_arrays,
    make_policy,
    run_collective,
)
from repro.sched.online import windowed_hier_lpt_schedule

M, N = 6, 4
CHUNK = 2**18


def _tm(seed: int = 0) -> TrafficMatrix:
    return sparse_topk_workload(
        M, N, sparsity=0.3, bytes_per_pair=2**18, top_k=3, seed=seed
    )


def _moe_tm(m: int, n: int, bytes_per_pair: float, top_k: int, seed: int) -> TrafficMatrix:
    """Gated sparse all-to-all: each sender GPU picks top_k remote
    (domain, gpu) experts with lognormal sizes — few large flows, where
    static lane spray leaves the WAN tier unbalanced."""
    rng = np.random.default_rng(seed)
    d1 = np.zeros((m, n, m, n))
    for d in range(m):
        for g in range(n):
            dsts = rng.choice(
                [x for x in range(m) if x != d], size=top_k, replace=False
            )
            for dd in dsts:
                gg = int(rng.integers(0, n))
                d1[d, g, int(dd), gg] = bytes_per_pair * rng.lognormal(0.0, 0.5)
    return TrafficMatrix(d1=d1, d2=d1.sum(axis=(1, 3)), name="moe-gated")


def _xdc_fabric(**kw) -> MultiPodFabric:
    args = dict(
        num_pods=4, domains_per_pod=2, num_rails=4,
        oversub=16.0, wan_rtt=10e-3, wan_lanes=4,
    )
    args.update(kw)
    return MultiPodFabric(**args)


# -- 1. flat-pod equivalence (CI gate: -k BitExact) ---------------------------


class TestBitExactFlatPod:
    @pytest.mark.parametrize("backend", ["event", "vector", "device"])
    @pytest.mark.parametrize("policy", ["ecmp", "rails", "hier-rails"])
    def test_p1_matches_rail_topology(self, backend, policy):
        tm = _tm()
        flat = run_collective(tm, policy, chunk_bytes=CHUNK, backend=backend)
        mp = run_collective(
            tm, policy, chunk_bytes=CHUNK, backend=backend,
            fabric=MultiPodFabric(num_pods=1, domains_per_pod=M, num_rails=N),
        )
        assert mp.makespan == flat.makespan
        assert mp.cct == flat.cct

    def test_hier_rails_degenerates_to_rails_on_flat(self):
        """With one pod there is no level-2 problem: hier-rails must
        reproduce the flat rail LPT chunk-for-chunk."""
        tm = _tm(seed=3)
        rails = run_collective(tm, "rails", chunk_bytes=CHUNK, backend="vector")
        hier = run_collective(tm, "hier-rails", chunk_bytes=CHUNK, backend="vector")
        assert hier.makespan == rails.makespan
        assert hier.cct == rails.cct

    def test_p1_geometry_matches(self):
        flat = RailTopology(M, N)
        mp = MultiPodFabric(num_pods=1, domains_per_pod=M, num_rails=N)
        assert mp.level_kinds == flat.level_kinds
        assert mp.num_pods == 1
        assert mp.inter_pod_cost_factor == 1.0
        for d in range(M):
            for dd in range(M):
                if d == dd:
                    continue
                assert mp.rail_path(d, dd, 1) == flat.rail_path(d, dd, 1)


class TestBitExactMultiPodBackends:
    @pytest.mark.parametrize("policy", ["rails", "hier-rails", "ecmp"])
    def test_event_vector_agree(self, policy):
        tm = _moe_tm(8, 4, 2**19, top_k=3, seed=2)
        topo = _xdc_fabric()
        ev = run_collective(
            tm, policy, chunk_bytes=CHUNK, fabric=topo, backend="event"
        )
        ve = run_collective(
            tm, policy, chunk_bytes=CHUNK, fabric=topo, backend="vector"
        )
        assert ve.makespan == pytest.approx(ev.makespan, rel=1e-9)
        for k in ev.cct:
            assert ve.cct[k] == pytest.approx(ev.cct[k], rel=1e-9)

    def test_device_matches_vector(self):
        """The jax backend runs the full multi-pod level structure (wan
        level + per-level latency) — float-tolerance contract, as on the
        flat fabric."""
        tm = _moe_tm(8, 4, 2**19, top_k=3, seed=2)
        topo = _xdc_fabric(oversub=4.0, wan_rtt=1e-3)
        ve = run_collective(
            tm, "hier-rails", chunk_bytes=CHUNK, fabric=topo, backend="vector"
        )
        de = run_collective(
            tm, "hier-rails", chunk_bytes=CHUNK, fabric=topo, backend="device"
        )
        assert de.makespan == pytest.approx(ve.makespan, rel=1e-9)


# -- 2. the hierarchy-aware scheduler -----------------------------------------


def _wan_lane_imbalance(tm, topo, policy_name):
    ja = build_job_arrays(tm, chunk_bytes=CHUNK)
    index = LinkIndex(topo)
    pol = make_policy(policy_name, topo, seed=0)
    lbl = pol.plan_arrays(ja, index)
    wan_links = lbl[:, index.level_of_kind["wan"]]
    loads = np.zeros(index.num_links)
    mask = wan_links >= 0
    np.add.at(loads, wan_links[mask], ja.size[mask])
    imbs = []
    for ps in range(topo.num_pods):
        for pd in range(topo.num_pods):
            if ps == pd:
                continue
            lane = loads[index.wan[ps, pd]]
            if lane.sum() > 0:
                imbs.append(lane.max() / lane.mean())
    return float(np.mean(imbs))


class TestHierRails:
    def test_beats_flat_on_gated_traffic(self):
        """The headline margin: two-level LPT cuts makespan on an
        oversubscribed 4-pod fabric carrying MoE-gated traffic. Seeded —
        the margin on this workload is ~6%; require >1% so the assert has
        slack without letting a regression to ~0 pass."""
        tm = _moe_tm(8, 4, 8 * 2**20, top_k=4, seed=1)
        topo = _xdc_fabric()
        flat = run_collective(
            tm, "rails", chunk_bytes=2 * 2**20, fabric=topo, backend="vector"
        )
        hier = run_collective(
            tm, "hier-rails", chunk_bytes=2 * 2**20, fabric=topo, backend="vector"
        )
        assert hier.makespan < flat.makespan * 0.99

    def test_wan_lanes_balanced(self):
        tm = _moe_tm(8, 4, 8 * 2**20, top_k=4, seed=1)
        topo = _xdc_fabric()
        imb_flat = _wan_lane_imbalance(tm, topo, "rails")
        imb_hier = _wan_lane_imbalance(tm, topo, "hier-rails")
        assert imb_hier < imb_flat
        assert imb_hier < 1.05

    def test_uniform_traffic_is_a_wash(self):
        """Dense uniform send keeps Theorem 3's symmetry one tier up: the
        static spray is already lane-balanced and hier-LPT must not lose
        anything for its extra machinery."""
        tm = uniform_workload(8, 4, bytes_per_pair=2**20)
        topo = _xdc_fabric()
        flat = run_collective(
            tm, "rails", chunk_bytes=2**19, fabric=topo, backend="vector"
        )
        hier = run_collective(
            tm, "hier-rails", chunk_bytes=2**19, fabric=topo, backend="vector"
        )
        assert hier.makespan <= flat.makespan * 1.005


class TestHierLptSchedule:
    def test_intra_pod_chunks_get_no_lane(self):
        w = np.array([4.0, 3.0, 2.0, 1.0])
        res = hier_lpt_schedule(w, 2, 3, np.array([0, 1, 0, 1]), src_pod=0)
        assert (res.lane[np.array([0, 2])] == -1).all()
        assert (res.lane[np.array([1, 3])] >= 0).all()

    def test_lane_loads_carry_balances_across_calls(self):
        """The per-source-pod carry: a second domain's chunks fill the
        lanes the first domain left lightest, so the pod's aggregate WAN
        load balances even though each call sees only its own chunks."""
        lane_loads = {}
        w = np.array([8.0, 1.0])
        dst = np.array([1, 1])
        hier_lpt_schedule(w, 2, 2, dst, src_pod=0, lane_loads=lane_loads)
        res2 = hier_lpt_schedule(w, 2, 2, dst, src_pod=0, lane_loads=lane_loads)
        total = lane_loads[1]
        assert total.max() / total.mean() == pytest.approx(1.0, abs=1e-9)
        assert res2.lane.min() >= 0

    def test_windowed_matches_offline_when_window_covers_all(self):
        rng = np.random.default_rng(5)
        w = rng.uniform(1, 10, size=32)
        dst = rng.integers(0, 3, size=32)
        off = hier_lpt_schedule(w, 4, 2, dst, src_pod=0)
        win = windowed_hier_lpt_schedule(w, 4, 2, dst, src_pod=0, window=None)
        np.testing.assert_array_equal(off.rail.assignment, win.rail.assignment)
        np.testing.assert_array_equal(off.lane, win.lane)


# -- 3. FEC vs go-back-N (seeded regression) ----------------------------------


class TestFecRecovery:
    def _run(self, rate: float, fec: FecConfig | None):
        tm = _moe_tm(8, 4, 8 * 2**20, top_k=4, seed=1)
        loss = LossConfig(rate=rate, rto=2 * 10e-3, links="wan")
        topo = _xdc_fabric(
            fault_spec=FaultSpec(loss=loss, fec=fec, seed=7)
        )
        return run_collective(
            tm, "hier-rails", chunk_bytes=2**20, fabric=topo, backend="event"
        )

    def test_fec_beats_gbn_under_wan_loss(self):
        """At 10 ms WAN RTT a go-back-N retransmission stalls the lane for
        the full RTO; XOR parity absorbs the same losses in-band. Seeded:
        on this draw FEC wins ~7% CCT."""
        gbn = self._run(0.01, None)
        fec = self._run(0.01, FecConfig(k=4, r=1))
        assert fec.makespan < gbn.makespan
        assert fec.goodput_bytes == pytest.approx(gbn.goodput_bytes)

    def test_fec_loses_at_zero_loss(self):
        """No losses to absorb: the r/k parity bandwidth is pure overhead
        and FEC must be measurably slower, never magically free."""
        clean = self._run(0.0, None)
        fec = self._run(0.0, FecConfig(k=4, r=1))
        assert fec.makespan > clean.makespan
