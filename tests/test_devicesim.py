"""Device-backend parity — the jax scan must match the vector backend.

The device backend (`repro.netsim.devicesim`) re-expresses the vector
prefix-scan dynamics as jitted `lax` / Pallas segmented scans over
fixed-shape padded arrays. The contract is *float tolerance* against the
vector backend (reductions reassociate on device; degenerate cross-link
ties may reorder — see the module docstring), plus three structural
invariants pinned here: padding buckets never change results, a vmap
batch of one equals the single-simulation entry point, and dynamic
FaultSpecs are rejected with an error naming the vector/event fallback.
"""

import numpy as np
import pytest

from repro.core.traffic import uniform_workload
from repro.netsim import FaultSpec, LinkIndex, run_collective, step_profile
from repro.netsim.devicesim import (
    PlannedJobs,
    bucket_size,
    check_device_supports,
    pad_job_arrays,
    simulate_chunk_arrays_device,
    simulate_many_device,
)
from repro.netsim.fastsim import paths_from_jobs, simulate_chunk_arrays
from repro.netsim.simulate import run_policy_suite
from repro.netsim.topology import RailTopology
from test_fastsim import CHUNK, M, N, _FixedPathPolicy, _random_jobs, _workloads

ALL_POLICIES = ("ecmp", "plb", "minrtt", "reps", "rails")


def _planned_random(topo, index, seed, num_chunks=200, spine_fraction=0.5):
    """Columns for one randomized fixed-path simulation (non-degenerate:
    random sizes and releases, so tie-order effects cannot hide behind
    equal-chunk waves and parity is tight)."""
    from repro.netsim.events import Engine

    rng = np.random.default_rng(seed)
    jobs, paths = _random_jobs(topo, rng, num_chunks, spine_fraction)
    ordered = _FixedPathPolicy(topo, paths).assign_batch(
        Engine(topo), jobs, now=0.0
    )
    lbl, rank = paths_from_jobs(ordered, index, num_chunks)
    size = np.zeros(num_chunks)
    release = np.zeros(num_chunks)
    for js in jobs.values():
        for j in js:
            size[j.chunk_id] = j.size
            release[j.chunk_id] = j.arrival_time
    return PlannedJobs(
        link_by_level=lbl, size=size, release=release, entry_rank=rank
    )


# -- randomized parity (the tight anchor) -------------------------------------


@pytest.mark.parametrize("spine_fraction", [0.0, 0.5, 1.0])
def test_device_matches_vector_randomized(spine_fraction):
    """Random sizes + releases, rail/spine paths mixed: per-chunk finish
    times match the vector backend at float tolerance."""
    topo = RailTopology(3, 3)
    index = LinkIndex(topo)
    for seed in (21, 22):
        p = _planned_random(topo, index, seed, 200, spine_fraction)
        res_v = simulate_chunk_arrays(
            index, p.link_by_level, p.size, p.release, p.entry_rank
        )
        res_d = simulate_chunk_arrays_device(
            index, p.link_by_level, p.size, p.release, p.entry_rank
        )
        np.testing.assert_allclose(res_d.finish, res_v.finish, rtol=1e-9)
        np.testing.assert_allclose(res_d.start, res_v.start, rtol=1e-9, atol=1e-18)
        assert np.isclose(res_d.makespan, res_v.makespan, rtol=1e-12)
        for link, volume in res_v.link_bytes.items():
            assert np.isclose(res_d.link_bytes[link], volume, rtol=1e-9)


def test_device_link_busy_carry_matches_vector():
    """The per-link busy-until carry (the gateway's window chaining)
    threads through the device scan identically."""
    topo = RailTopology(3, 3)
    index = LinkIndex(topo)
    p1 = _planned_random(topo, index, 31, 150, 0.5)
    p2 = _planned_random(topo, index, 32, 150, 0.5)
    busy = np.zeros(index.num_links)
    rv1 = simulate_chunk_arrays(
        index, p1.link_by_level, p1.size, p1.release, p1.entry_rank,
        link_busy=busy,
    )
    rd1 = simulate_chunk_arrays_device(
        index, p1.link_by_level, p1.size, p1.release, p1.entry_rank,
        link_busy=busy,
    )
    np.testing.assert_allclose(rd1.link_last, rv1.link_last, rtol=1e-9)
    rv2 = simulate_chunk_arrays(
        index, p2.link_by_level, p2.size, p2.release, p2.entry_rank,
        link_busy=rv1.link_last,
    )
    rd2 = simulate_chunk_arrays_device(
        index, p2.link_by_level, p2.size, p2.release, p2.entry_rank,
        link_busy=rd1.link_last,
    )
    np.testing.assert_allclose(rd2.finish, rv2.finish, rtol=1e-9)
    assert np.isclose(rd2.makespan, rv2.makespan, rtol=1e-12)


# -- end-to-end parity on the paper workloads ---------------------------------


@pytest.mark.parametrize("policy", ("rails", "ecmp"))
def test_device_matches_vector_collectives(policy):
    """run_collective(backend="device") matches the vector backend on the
    paper workloads: makespan at fp tolerance everywhere; CCT stats at
    2e-2 for non-rails policies (equal-size chunk waves at t=0 are
    massively degenerate — every flow in a wave ties — and device
    tie-breaking may pick a different, equally valid, FIFO schedule that
    shifts mid-distribution percentiles by a service quantum; the
    randomized tests above are the tight anchor)."""
    for name, tm in _workloads().items():
        v = run_collective(tm, policy, chunk_bytes=CHUNK, seed=3, backend="vector")
        d = run_collective(tm, policy, chunk_bytes=CHUNK, seed=3, backend="device")
        assert np.isclose(d.makespan, v.makespan, rtol=1e-9), (policy, name)
        cct_rtol = 1e-9 if policy == "rails" else 2e-2
        for key, val in v.cct.items():
            assert np.isclose(d.cct[key], val, rtol=cct_rtol, atol=1e-15), (
                policy, name, key,
            )
        np.testing.assert_allclose(d.nic_tx, v.nic_tx, rtol=1e-9)
        np.testing.assert_allclose(d.nic_rx, v.nic_rx, rtol=1e-9)


def test_policy_suite_device_batches_whole_grid():
    """run_policy_suite(backend="device") — one vmap dispatch for every
    policy — matches the per-policy vector loop."""
    tm = _workloads()["sparse04"]
    vec = run_policy_suite(tm, ALL_POLICIES, chunk_bytes=CHUNK, seed=3,
                           backend="vector")
    dev = run_policy_suite(tm, ALL_POLICIES, chunk_bytes=CHUNK, seed=3,
                           backend="device")
    assert set(dev) == set(vec)
    for p in ALL_POLICIES:
        assert np.isclose(dev[p].makespan, vec[p].makespan, rtol=1e-9), p
        cct_rtol = 1e-9 if p == "rails" else 2e-2
        for key, val in vec[p].cct.items():
            assert np.isclose(dev[p].cct[key], val, rtol=cct_rtol), (p, key)


# -- structural invariants ----------------------------------------------------


def test_padding_invariance():
    """Results are invariant to the padding bucket: the default bucket and
    a 4x larger one produce bit-identical outputs (padded chunks are
    zero-service tail segments by construction)."""
    topo = RailTopology(3, 3)
    index = LinkIndex(topo)
    p = _planned_random(topo, index, 41, 100, 0.5)
    base = bucket_size(p.num_chunks)
    r1 = simulate_chunk_arrays_device(
        index, p.link_by_level, p.size, p.release, p.entry_rank, bucket=base
    )
    r2 = simulate_chunk_arrays_device(
        index, p.link_by_level, p.size, p.release, p.entry_rank,
        bucket=4 * base,
    )
    np.testing.assert_array_equal(r1.finish, r2.finish)
    np.testing.assert_array_equal(r1.start, r2.start)
    assert r1.makespan == r2.makespan
    assert r1.link_bytes == r2.link_bytes


def test_pad_job_arrays_contract():
    """Padding appends after the valid prefix: sentinel links, zero size,
    past-end ranks; a bucket smaller than the job count is an error."""
    topo = RailTopology(3, 3)
    index = LinkIndex(topo)
    p = _planned_random(topo, index, 42, 50, 0.0)
    lbl, size, release, rank, valid = pad_job_arrays(p)
    b = bucket_size(50)
    assert lbl.shape[0] == size.size == release.size == rank.size == b
    assert valid[:50].all() and not valid[50:].any()
    np.testing.assert_array_equal(lbl[:50], p.link_by_level)
    assert (lbl[50:] == -1).all()
    assert (size[50:] == 0.0).all()
    np.testing.assert_array_equal(rank[50:], np.arange(50, b))
    with pytest.raises(ValueError, match="bucket"):
        pad_job_arrays(p, bucket=32)


def test_batch_of_one_matches_single():
    """simulate_many_device([p]) — the vmap-ed batch path — is bit-identical
    to the single-simulation entry point on the same bucket."""
    topo = RailTopology(3, 3)
    index = LinkIndex(topo)
    p = _planned_random(topo, index, 43, 120, 0.5)
    single = simulate_chunk_arrays_device(
        index, p.link_by_level, p.size, p.release, p.entry_rank
    )
    (batched,) = simulate_many_device(index, [p])
    np.testing.assert_array_equal(batched.finish, single.finish)
    np.testing.assert_array_equal(batched.start, single.start)
    assert batched.makespan == single.makespan


def test_batch_members_match_separate_calls():
    """A heterogeneous batch (different job counts → shared bucket) gives
    each member the same answer as running it alone."""
    topo = RailTopology(3, 3)
    index = LinkIndex(topo)
    ps = [
        _planned_random(topo, index, 51, 60, 0.0),
        _planned_random(topo, index, 52, 140, 0.5),
        _planned_random(topo, index, 53, 90, 1.0),
    ]
    batch = simulate_many_device(index, ps)
    for p, b in zip(ps, batch):
        alone = simulate_chunk_arrays_device(
            index, p.link_by_level, p.size, p.release, p.entry_rank
        )
        np.testing.assert_allclose(b.finish, alone.finish, rtol=1e-12)
        assert np.isclose(b.makespan, alone.makespan, rtol=1e-12)


def test_interpret_kernel_matches_lax():
    """The Pallas lane-scan kernel (interpret mode on CPU) is numerically
    identical to the associative-scan fallback — the parity CI relies on
    this to validate the kernel without an accelerator."""
    topo = RailTopology(3, 3)
    index = LinkIndex(topo)
    p = _planned_random(topo, index, 61, 100, 0.5)
    r_lax = simulate_chunk_arrays_device(
        index, p.link_by_level, p.size, p.release, p.entry_rank, impl="lax"
    )
    r_pal = simulate_chunk_arrays_device(
        index, p.link_by_level, p.size, p.release, p.entry_rank,
        impl="pallas_interpret",
    )
    np.testing.assert_allclose(r_pal.finish, r_lax.finish, rtol=1e-12)
    assert np.isclose(r_pal.makespan, r_lax.makespan, rtol=1e-12)


def test_bucket_sizes_are_bounded_powers_of_two():
    assert bucket_size(1) == 256  # MIN_BUCKET floor
    assert bucket_size(256) == 256
    assert bucket_size(257) == 512
    assert bucket_size(1000) == 1024


# -- unsupported-dynamics rejection -------------------------------------------


def test_device_rejects_dynamic_fault_spec_naming_fallback():
    """Non-constant LinkModels raise NotImplementedError naming the
    vector (static) and event (dynamic) fallbacks; an *unspecified*
    backend still silently falls back to the event engine."""
    spec = FaultSpec(rail_profiles={0: step_profile(1e-3, 0.5)})
    with pytest.raises(NotImplementedError, match="vector"):
        check_device_supports(RailTopology(2, 2, fault_spec=spec))
    tm = uniform_workload(2, 2, bytes_per_pair=CHUNK)
    with pytest.raises(NotImplementedError, match="backend='event'"):
        run_collective(
            tm, "rails", chunk_bytes=CHUNK, backend="device", fault_spec=spec
        )
    # No explicit backend: dynamics resolve to the event engine as before.
    res = run_collective(tm, "rails", chunk_bytes=CHUNK, fault_spec=spec)
    assert res.makespan > 0.0


def test_device_accepts_constant_fault_spec():
    """Constant-profile specs fold into static rates — supported, and in
    parity with the vector backend."""
    spec = FaultSpec(rail_profiles={0: 1.0, 1: 0.5})
    tm = uniform_workload(M, N, bytes_per_pair=8 * 2**20)
    v = run_collective(
        tm, "rails", chunk_bytes=CHUNK, seed=3, backend="vector", fault_spec=spec
    )
    d = run_collective(
        tm, "rails", chunk_bytes=CHUNK, seed=3, backend="device", fault_spec=spec
    )
    assert np.isclose(d.makespan, v.makespan, rtol=1e-9)


# -- downstream consumers -----------------------------------------------------


def test_score_placements_batch_matches_loop():
    """Placement candidate scoring: the one-dispatch batch equals the
    per-candidate device loop exactly, and the vector loop at tolerance."""
    from repro.placement.search import (
        greedy_placement,
        score_placement,
        score_placements_batch,
        static_placement,
    )

    rng = np.random.default_rng(7)
    counts = rng.integers(0, 200, size=(4, 16)).astype(float)
    bpt = 16 * 2**10
    pls = [static_placement(16, 4), greedy_placement(counts, 4)]
    batch = score_placements_batch(counts, pls, 4, bpt)
    for score, pl in zip(batch, pls):
        dev = score_placement(counts, pl, 4, bpt, backend="device")
        vec = score_placement(counts, pl, 4, bpt, backend="vector")
        assert score == dev
        assert np.isclose(score, vec, rtol=1e-9)
