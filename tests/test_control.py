"""Serving control plane (`repro.sched.control` + `repro.serve.gateway`).

Anchors:

1. **BitExact control-off** — `run_gateway(control=None)` delegates to
   `run_serving` verbatim on both backends: identical TTFT / sojourn
   vectors (the CI parity gate keys on the BitExact class name).
2. **Shedding monotone** — with a fixed token-bucket admission rate, the
   shed rate is non-decreasing in offered load.
3. **SLO attainment non-increasing** — without admission control, the
   fraction of requests meeting the TTFT SLO cannot improve as load
   rises on a degraded fabric.
4. **Brownout hysteresis** — a mid-trace rail cut (piecewise
   `fabric_schedule`) enters brownout; the repair plus the probe
   monitor's revive hysteresis exits it.
5. **Epoch-windowed loop** — the gateway's vector window chaining
   (per-link busy carry) agrees with single-shot simulation, and with
   the event-loop feedback path on small traces.
6. **Revive hysteresis** — `DeadRailDetector` demands K consecutive
   in-deadline beats before re-admitting a FAILED rail.
7. **RL phase workload** — `rl_phase_counts` lurches at phase
   boundaries: cross-boundary L1 distance dwarfs within-phase drift.
8. **Empty-sample guards** — fully-shed windows (no served requests, no
   bytes moved) report zeros instead of raising.
"""

import numpy as np
import pytest

from repro.core.traffic import (
    TrafficMatrix,
    rl_phase_counts,
    serve_workload,
    uniform_workload,
)
from repro.netsim.balancers import make_policy
from repro.netsim.events import Engine
from repro.netsim.fastsim import LinkIndex, paths_from_jobs, simulate_chunk_arrays
from repro.netsim.simulate import build_streaming_jobs, run_streaming_collective
from repro.netsim.topology import RailTopology
from repro.sched.control import (
    AdmissionConfig,
    AdmissionController,
    BrownoutConfig,
    BrownoutController,
    ControlConfig,
    RailProbeMonitor,
    TokenBucket,
    slo_summary,
)
from repro.sched.feedback import DeadRailDetector, RailHealthEstimator
from repro.sched.serving import run_serving
from repro.serve.gateway import run_gateway

M, N = 4, 4


def _wl(num_requests=40, mean_gap=2e-3, seed=1, **kw):
    return serve_workload(M, N, num_requests=num_requests, mean_gap=mean_gap,
                          seed=seed, **kw)


def _assigned_arrays(policy, topo, index, rounds, chunk_bytes=1 * 2**20):
    """Rounds → vector-sim input arrays, the gateway's per-window recipe."""
    jobs = build_streaming_jobs(rounds, chunk_bytes)
    policy.prepare(jobs)
    rel_batches = {}
    num_chunks = 0
    for key, js in jobs.items():
        for j in js:
            rel_batches.setdefault(j.arrival_time, {}).setdefault(key, []).append(j)
            num_chunks += 1
    eng = Engine(topo)
    ordered = []
    for t in sorted(rel_batches):
        ordered.extend(policy.assign_batch(eng, rel_batches[t], now=t))
    link_by_level, entry_rank = paths_from_jobs(ordered, index, num_chunks)
    size = np.empty(num_chunks)
    release = np.empty(num_chunks)
    round_id = np.empty(num_chunks, dtype=np.int64)
    for j in ordered:
        cid = j.chunk_id
        size[cid] = j.size
        release[cid] = j.arrival_time
        round_id[cid] = j.round_id
    return link_by_level, size, release, entry_rank, round_id


# -- token bucket -------------------------------------------------------------


class TestTokenBucket:
    def test_starts_full_and_refills(self):
        b = TokenBucket(rate=10.0, burst=2.0)
        assert b.allow(0.0) and b.allow(0.0)
        assert not b.allow(0.0)  # burst exhausted
        assert b.allow(0.1)  # 0.1 s x 10 rps = 1 token back
        assert not b.allow(0.1)

    def test_burst_caps_refill(self):
        b = TokenBucket(rate=10.0, burst=2.0)
        assert b.allow(0.0) and b.allow(0.0)
        # A long quiet period refills to the cap, not beyond it.
        assert b.allow(100.0) and b.allow(100.0)
        assert not b.allow(100.0)

    def test_set_rate(self):
        b = TokenBucket(rate=10.0, burst=1.0)
        assert b.allow(0.0)
        b.set_rate(100.0)
        assert b.allow(0.01)  # refilled at the new rate


# -- control-off bit-exactness (CI gate: -k BitExact) -------------------------


class TestBitExactControlOff:
    @pytest.mark.parametrize("backend", ["event", "vector"])
    def test_gateway_delegates_bit_exact(self, backend):
        wl = _wl()
        base = run_serving(wl, "rails-online", backend=backend)
        gw = run_gateway(wl, "rails-online", control=None, backend=backend)
        assert np.array_equal(base.request.ttft, gw.request.ttft)
        assert np.array_equal(base.request.token_latency,
                              gw.request.token_latency)
        assert np.array_equal(base.request.sojourn, gw.request.sojourn)
        assert gw.served_mask.all() and not gw.shed_reason
        assert gw.serving is not None

    def test_zero_link_busy_carry_is_identity(self):
        # The epoch loop's foundation: an all-zeros carry must be
        # bit-identical to passing no carry at all.
        topo = RailTopology(M, N)
        index = LinkIndex(topo)
        tm = uniform_workload(M, N, bytes_per_pair=2 * 2**20)
        rounds = [(0.0, tm), (1e-4, tm)]
        arrays = _assigned_arrays(
            make_policy("rails-online", topo), topo, index, rounds
        )
        res0 = simulate_chunk_arrays(index, *arrays[:4], round_id=arrays[4])
        res1 = simulate_chunk_arrays(
            index, *arrays[:4], round_id=arrays[4],
            link_busy=np.zeros(index.num_links),
        )
        assert np.array_equal(res0.finish, res1.finish)
        assert res0.link_last is None and res1.link_last is not None


# -- link-busy window chaining ------------------------------------------------


class TestWindowChaining:
    def test_split_stream_matches_whole_stream(self):
        # Two bursts far enough apart that burst 1 drains before burst 2
        # releases: splitting at the quiet boundary and carrying link_last
        # must reproduce the single-shot completions exactly. The planner
        # state persists across the split, exactly like the gateway's.
        topo = RailTopology(M, N)
        index = LinkIndex(topo)
        tm = uniform_workload(M, N, bytes_per_pair=2 * 2**20)
        gap = 0.5  # far beyond the burst's makespan
        rounds = [(0.0, tm), (gap, tm)]

        whole = _assigned_arrays(
            make_policy("rails-online", topo), topo, index, rounds
        )
        res_whole = simulate_chunk_arrays(
            index, *whole[:4], round_id=whole[4]
        )
        fins_whole = res_whole.round_completion_times()

        policy = make_policy("rails-online", topo)  # persistent LptState
        carry = np.zeros(index.num_links)
        fins_split = {}
        for i, rnd in enumerate(rounds):
            part = _assigned_arrays(policy, topo, index, [rnd])
            res = simulate_chunk_arrays(
                index, *part[:4], round_id=part[4], link_busy=carry,
            )
            carry = res.link_last
            fins_split[i] = res.round_completion_times()[0]
        for i in fins_whole:
            assert fins_split[i] == pytest.approx(fins_whole[i], rel=1e-12)


# -- admission control: shedding monotone in offered load ---------------------


class TestShedding:
    def _run(self, mean_gap):
        wl = _wl(num_requests=120, mean_gap=mean_gap, seed=7)
        ctl = ControlConfig(
            slo_s=0.05, admission=AdmissionConfig(rate_rps=400.0, burst=4.0)
        )
        return run_gateway(wl, "rails-online", control=ctl, backend="vector")

    def test_shed_rate_monotone_in_load(self):
        rates = [self._run(g).slo["shed_rate"] for g in (8e-3, 2e-3, 5e-4)]
        assert rates == sorted(rates)
        assert rates[-1] > 0.0  # the overloaded point actually sheds

    def test_decode_rounds_never_shed(self):
        gw = self._run(5e-4)
        # Every served request got its full TTFT + all decode members.
        served = int(gw.served_mask.sum())
        decode_per_req = gw.workload.requests[0].decode_rounds
        assert gw.request.token_latency.size == served * decode_per_req
        # And shed requests are excluded from the percentiles entirely.
        assert gw.request.ttft.size == served

    def test_shed_reasons_recorded(self):
        gw = self._run(5e-4)
        assert gw.shed_reason
        assert set(gw.shed_reason.values()) <= {"bucket", "queue", "p99"}

    def test_queue_limit_sheds(self):
        wl = _wl(num_requests=60, mean_gap=5e-4, seed=7)
        ctl = ControlConfig(
            slo_s=0.05, admission=AdmissionConfig(queue_limit=2)
        )
        gw = run_gateway(wl, "rails-online", control=ctl, backend="vector")
        assert "queue" in set(gw.shed_reason.values())


# -- SLO attainment non-increasing in load ------------------------------------


class TestSloAttainment:
    def test_uncontrolled_attainment_non_increasing(self):
        # Inert control (no admission, no brownout) on a degraded fabric:
        # rising load can only push more TTFTs past the SLO.
        speeds = np.ones(N)
        speeds[-1] = 0.05
        fracs = []
        for gap in (4e-3, 1e-3, 2.5e-4):
            wl = _wl(num_requests=80, mean_gap=gap, seed=5)
            ctl = ControlConfig(slo_s=0.002)
            gw = run_gateway(
                wl, "rails-online", control=ctl, rail_speeds=speeds,
                backend="vector",
            )
            fracs.append(gw.slo["slo_met"] / gw.slo["offered"])
        assert fracs[0] >= fracs[1] >= fracs[2]


# -- brownout: entry on rail cut, exit after repair ---------------------------


class TestBrownout:
    def test_entry_and_exit_on_rail_cut(self):
        wl = _wl(num_requests=200, mean_gap=1e-3, seed=2)
        span = max(r.release for r in wl.rounds) - min(
            r.release for r in wl.rounds
        )
        healthy = np.ones(N)
        cut = healthy.copy()
        cut[0] = 0.02
        schedule = [
            (0.0, healthy),
            (0.25 * span, cut),
            (0.55 * span, healthy),
        ]
        ctl = ControlConfig(
            slo_s=0.05,
            epoch_s=span / 40.0,
            admission=AdmissionConfig(rate_rps=5000.0),
            brownout=BrownoutConfig(),
            revive_windows=2,
        )
        gw = run_gateway(
            wl, "rails-online", control=ctl, fabric_schedule=schedule,
            backend="vector",
        )
        assert gw.brownout.entries, "rail cut must trigger brownout"
        assert gw.brownout.exits, "repair + revive hysteresis must exit it"
        assert gw.brownout.entries[0] < gw.brownout.exits[0]
        assert 0 in gw.monitor.masked_at and 0 in gw.monitor.revived_at
        modes = [w.mode for w in gw.windows]
        assert "brownout" in modes and modes[-1] == "normal"

    def test_probe_monitor_masks_and_revives(self):
        health = RailHealthEstimator(N, nominal_rate=50e9)
        mon = RailProbeMonitor(health, dead_speed=0.2, healthy_speed=0.6,
                               revive_windows=2)
        dead = np.ones(N)
        dead[1] = 0.01
        for k in range(4):
            mon.observe(dead, 0.01 * (k + 1))
        assert not mon.survivor_mask()[1]
        # Recovery is doubly damped: the EWMA must climb back above
        # healthy_speed first, and only then does the revive streak count.
        mon.observe(np.ones(N), 0.05)
        assert not mon.survivor_mask()[1]
        for k in range(12):
            mon.observe(np.ones(N), 0.06 + 0.01 * k)
        assert mon.survivor_mask()[1]
        assert mon.masked_at[1] < mon.revived_at[1]


# -- epoch-windowed loop parity -----------------------------------------------


class TestEpochLoopParity:
    def test_inert_control_matches_vector_single_shot(self):
        # Control on but every controller disabled, healthy fabric, no
        # batching: the windowed loop must reproduce the single-shot
        # vector serving run (same planner state evolution, exact FIFO
        # chaining through the busy carry).
        wl = _wl(num_requests=40, mean_gap=4e-3, seed=3)
        base = run_serving(wl, "rails-online", backend="vector")
        gw = run_gateway(
            wl, "rails-online",
            control=ControlConfig(slo_s=0.05, feedback=False),
            backend="vector",
        )
        assert gw.served_mask.all()
        np.testing.assert_allclose(gw.request.ttft, base.request.ttft,
                                   rtol=1e-9)
        np.testing.assert_allclose(gw.request.sojourn, base.request.sojourn,
                                   rtol=1e-9)

    def test_inert_control_matches_event_feedback_path(self):
        # Small-trace agreement with the event-loop feedback path: on a
        # healthy fabric the EWMA pre-charge is ~zero on both sides, so
        # the two loops land on the same tails.
        wl = _wl(num_requests=30, mean_gap=4e-3, seed=4)
        base = run_serving(wl, "rails-online", backend="event", feedback=True)
        gw = run_gateway(
            wl, "rails-online",
            control=ControlConfig(slo_s=0.05, feedback=True),
            backend="vector",
        )
        np.testing.assert_allclose(gw.request.ttft, base.request.ttft,
                                   rtol=1e-6)

    def test_continuous_batching_preserves_members(self):
        wl = _wl(num_requests=40, mean_gap=1e-3, seed=6)
        ctl = ControlConfig(slo_s=0.05, batch_quantum_s=2e-3)
        gw = run_gateway(wl, "rails-online", control=ctl, backend="vector")
        decode_per_req = wl.requests[0].decode_rounds
        # Every decode member reports a latency even when batched...
        assert gw.request.token_latency.size == len(wl.requests) * decode_per_req
        # ...and batching genuinely merged rounds.
        simulated = sum(w.rounds for w in gw.windows)
        assert simulated < len(wl.rounds)

    def test_event_backend_controlled_loop_runs(self):
        wl = _wl(num_requests=30, mean_gap=1e-3, seed=8)
        ctl = ControlConfig(
            slo_s=0.05, admission=AdmissionConfig(rate_rps=800.0)
        )
        gw = run_gateway(wl, "rails-online", control=ctl, backend="event")
        assert gw.slo["served"] + gw.slo["shed"] == gw.slo["offered"]
        assert gw.windows


# -- dead-rail revive hysteresis ----------------------------------------------


class _Beat:
    def __init__(self, size):
        self.size = size


class TestReviveHysteresis:
    def _fail_rail(self, det, rail=0, other=1):
        # Silence rail 0 while rail `other` keeps beating past the deadline.
        det.record_service(f"up:0:{rail}", 0.0, 0.01, _Beat(1.0))
        for k in range(30):
            det.record_service(f"up:0:{other}", 0.1 * k, 0.1 * k + 0.01,
                               _Beat(1.0))
        det.sweep(3.0)
        assert not det.survivor_mask()[rail]

    def test_default_is_immediate_revive(self):
        det = DeadRailDetector(N, deadline=1.0)
        self._fail_rail(det)
        det.record_service("up:0:0", 3.0, 3.01, _Beat(1.0))
        assert det.survivor_mask()[0]

    def test_k_consecutive_beats_required(self):
        det = DeadRailDetector(N, deadline=1.0, revive_hysteresis=3)
        self._fail_rail(det)
        det.record_service("up:0:0", 3.0, 3.01, _Beat(1.0))
        det.record_service("up:0:0", 3.1, 3.11, _Beat(1.0))
        assert not det.survivor_mask()[0]  # 2 of 3
        det.record_service("up:0:0", 3.2, 3.21, _Beat(1.0))
        assert det.survivor_mask()[0]
        assert 0 in det.recovered_at

    def test_flapping_rail_never_revives(self):
        # Beats separated by more than the deadline reset the streak: a
        # flapping lane (one beat per silence window) stays FAILED.
        det = DeadRailDetector(N, deadline=1.0, revive_hysteresis=2)
        self._fail_rail(det)
        for k in range(5):
            t = 3.0 + 2.5 * k  # gaps of 2.5 s >> 1 s deadline
            det.record_service("up:0:0", t, t + 0.01, _Beat(1.0))
        assert not det.survivor_mask()[0]

    def test_hysteresis_validation(self):
        with pytest.raises(ValueError):
            DeadRailDetector(N, deadline=1.0, revive_hysteresis=0)


# -- RL phase workload --------------------------------------------------------


class TestRlPhaseCounts:
    def test_phase_boundaries_shift_distribution(self):
        rounds, _shard, phases = rl_phase_counts(
            M, 16, num_rounds=32, tokens_per_round=4096.0,
            rollout_len=8, train_len=8, drift=0.01, seed=0,
            return_phases=True,
        )
        counts = np.stack(rounds)
        assert len(phases) == 32 and counts.shape[0] == 32

        def dist(a, b):
            pa = counts[a].sum(axis=0) / counts[a].sum()
            pb = counts[b].sum(axis=0) / counts[b].sum()
            return float(np.abs(pa - pb).sum())

        within = [dist(r, r + 1) for r in range(32 - 1)
                  if phases[r] == phases[r + 1]]
        across = [dist(r, r + 1) for r in range(32 - 1)
                  if phases[r] != phases[r + 1]]
        assert across, "trace must contain phase boundaries"
        # The lurch at a boundary dwarfs the within-phase drift.
        assert min(across) > 5.0 * max(within)

    def test_phase_schedule(self):
        _c, _s, phases = rl_phase_counts(
            M, 8, num_rounds=10, tokens_per_round=512.0,
            rollout_len=3, train_len=2, return_phases=True,
        )
        assert phases == ["rollout"] * 3 + ["train"] * 2 + ["rollout"] * 3 + [
            "train"
        ] * 2

    def test_counts_conserve_tokens(self):
        rounds, _ = rl_phase_counts(M, 8, num_rounds=6,
                                    tokens_per_round=1000.0)
        np.testing.assert_allclose(np.stack(rounds).sum(axis=(1, 2)), 1000.0)


# -- empty-sample guards ------------------------------------------------------


class TestEmptyGuards:
    def test_slo_summary_fully_shed(self):
        s = slo_summary(np.array([]), 0.05, horizon_s=1.0, offered=10,
                        shed=10)
        assert s["served"] == 0 and s["shed_rate"] == 1.0
        assert s["slo_attainment"] == 0.0 and s["goodput_rps"] == 0.0

    def test_zero_byte_collective_opt_ratio(self):
        # All traffic intra-domain: no chunks, makespan 0 — trivially
        # optimal, not infinitely bad.
        d1 = np.zeros((M, N, M, N))
        tm = TrafficMatrix(d1=d1, d2=d1.sum(axis=(1, 3)), name="empty")
        stream = run_streaming_collective([(0.0, tm)], "rails",
                                          backend="event")
        assert stream.metrics.makespan == 0.0
        assert stream.metrics.opt_ratio == 1.0

    def test_admission_observe_window_none_is_noop(self):
        ctl = AdmissionController(AdmissionConfig(rate_rps=10.0), slo_s=0.05)
        ctl.observe_window(None)  # fully-shed window: no p99 sample
        ok, reason = ctl.admit(0.0, inflight=0)
        assert ok and reason == "admitted"


# -- SLO headline: control beats no-control on a dead-rail fabric -------------


class TestControlBeatsBaseline:
    @pytest.mark.parametrize("mean_gap", [2e-4, 1e-4, 5e-5])
    def test_goodput_higher_with_control_on_dead_rail(self, mean_gap):
        speeds = np.ones(N)
        speeds[-1] = 0.02
        slo = 0.002
        wl = _wl(num_requests=300, mean_gap=mean_gap, seed=9)
        # True no-control baseline: plain run_serving delegation — the
        # planner sprays over every rail, dead one included, and the
        # dead rail's backlog drags p99 TTFT far past the SLO.
        base = run_gateway(
            wl, "rails-online", control=None, rail_speeds=speeds,
            backend="vector", slo_s=slo,
        )
        ctl = ControlConfig(
            slo_s=slo,
            admission=AdmissionConfig(rate_rps=4000.0),
            brownout=BrownoutConfig(),
        )
        controlled = run_gateway(
            wl, "rails-online", control=ctl, rail_speeds=speeds,
            backend="vector",
        )
        # Strictly higher goodput at p99 TTFT <= SLO — the acceptance
        # headline — by a wide margin, not a tie-break.
        assert controlled.slo["goodput_rps"] > 2.0 * base.slo["goodput_rps"]
