"""Link-dynamics layer: profiles, PFC/ECN/loss mechanics, and the §VI-E
fault regression.

Covers the contract the refactor promises: constant profiles cost nothing
(bit-exact with the static fabric on both backends), the vector backend
rejects non-static specs by name, go-back-N delivers every chunk exactly
once, the EWMA health estimator *tracks* a mid-run speed step, and the
seeded 1%-loss + flapping-rail scenario reproduces the paper's qualitative
§VI-E ordering (proactive rails + feedback < reactive baselines).
"""

import numpy as np
import pytest

from repro.core.theorems import theorem2_optimal_time
from repro.core.traffic import (
    bursty_release_times,
    microbatch_stream,
    receiver_skew_workload,
    uniform_workload,
)
from repro.netsim import (
    EcnConfig,
    Engine,
    FaultSpec,
    LinkIndex,
    LossConfig,
    PfcConfig,
    PiecewiseRate,
    RailTopology,
    build_jobs,
    flapping_profile,
    run_collective,
    run_streaming_collective,
    speeds_at,
    step_profile,
)
from repro.netsim.balancers import make_policy
from repro.runtime.straggler import degraded_rail_schedule
from repro.sched.feedback import RailHealthEstimator

M, N = 4, 4
B = 8 * 2**20
CHUNK = 1 * 2**20


def _stream(rounds=6, seed=1, rel_seed=2):
    tms = microbatch_stream(M, N, rounds, bytes_per_pair=B / rounds, seed=seed)
    gap = 0.5 * theorem2_optimal_time(tms[0].d2, N, 50e9)
    releases = bursty_release_times(rounds, gap, seed=rel_seed)
    return list(zip(releases, tms))


# -- profiles ----------------------------------------------------------------


def test_piecewise_profile_integration():
    p = step_profile(5.0, 0.5)
    assert p.factor_at(0.0) == 1.0 and p.factor_at(5.0) == 0.5
    assert p.next_change(0.0) == 5.0 and p.next_change(5.0) == float("inf")
    # 10 bytes at rate 1: 5 bytes by t=5, the rest at 0.5 B/s -> t=15.
    assert p.service_finish(0.0, 10.0, 1.0) == 15.0
    # Entirely inside one segment: plain division.
    assert p.service_finish(0.0, 2.0, 1.0) == 2.0
    assert p.service_finish(6.0, 2.0, 1.0) == 10.0


def test_flapping_profile_is_periodic():
    p = flapping_profile(period=10.0, duty=0.5, low=0.25)
    assert p.factor_at(1.0) == 1.0 and p.factor_at(6.0) == 0.25
    assert p.factor_at(11.0) == 1.0 and p.factor_at(16.0) == 0.25
    assert p.next_change(1.0) == 5.0
    assert p.next_change(6.0) == 10.0
    assert p.next_change(12.0) == 15.0
    # One full period at mean rate 0.625: 6.25 bytes per 10 s at rate 1.
    assert p.service_finish(0.0, 6.25, 1.0) == 10.0


def test_profile_validation():
    with pytest.raises(ValueError, match="increasing"):
        PiecewiseRate((2.0, 1.0), (1.0, 0.5, 0.25))
    with pytest.raises(ValueError, match="factors"):
        PiecewiseRate((1.0,), (1.0,))
    with pytest.raises(ValueError, match="positive"):
        PiecewiseRate((1.0,), (1.0, 0.0))
    with pytest.raises(ValueError, match="period"):
        PiecewiseRate((2.0,), (1.0, 0.5), period=1.5)
    with pytest.raises(ValueError, match="duty"):
        flapping_profile(10.0, 1.5, 0.5)


# -- constant profiles cost nothing ------------------------------------------


@pytest.mark.parametrize("backend", ["event", "vector"])
@pytest.mark.parametrize("policy", ["rails", "reps"])
def test_constant_profile_bit_exact(backend, policy):
    """A FaultSpec of constant profiles is the static fabric, bit for bit,
    on both backends — the dynamics layer costs nothing when inactive."""
    tm = uniform_workload(M, N, bytes_per_pair=B)
    base = run_collective(tm, policy, chunk_bytes=CHUNK, seed=3, backend=backend)
    spec = FaultSpec(rail_profiles={0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0})
    assert spec.is_static
    got = run_collective(
        tm, policy, chunk_bytes=CHUNK, seed=3, backend=backend, fault_spec=spec
    )
    assert got.makespan == base.makespan
    assert got.cct == base.cct


def test_constant_profile_folds_like_rail_speeds():
    """rail_speeds sugar == the same factors delivered as constant
    profiles (both fold into the static link rate)."""
    tm = uniform_workload(M, N, bytes_per_pair=B)
    speeds = [1.0, 0.8, 1.0, 0.5]
    a = run_collective(
        tm, "rails", chunk_bytes=CHUNK, backend="event", rail_speeds=speeds
    )
    b = run_collective(
        tm, "rails", chunk_bytes=CHUNK, backend="event",
        fault_spec=FaultSpec(rail_profiles=dict(enumerate(speeds))),
    )
    assert a.makespan == b.makespan
    assert a.cct == b.cct


def test_vector_backend_rejects_dynamics():
    tm = uniform_workload(M, N, bytes_per_pair=B)
    spec = FaultSpec(loss=LossConfig(rate=0.01, rto=1e-4))
    with pytest.raises(ValueError, match="event"):
        run_collective(tm, "rails", chunk_bytes=CHUNK, backend="vector", fault_spec=spec)
    with pytest.raises(ValueError, match="event"):
        run_streaming_collective(
            tm, "rails", chunk_bytes=CHUNK, backend="vector", fault_spec=spec
        )
    with pytest.raises(ValueError, match="event"):
        LinkIndex(RailTopology(M, N, fault_spec=spec))
    # Unspecified backend silently falls back to the event engine.
    m = run_collective(tm, "rails", chunk_bytes=CHUNK, fault_spec=spec)
    assert m.makespan > 0


def test_dynamics_reject_flowlet_coalescing():
    tm = uniform_workload(M, N, bytes_per_pair=B)
    spec = FaultSpec(loss=LossConfig(rate=0.01, rto=1e-4))
    with pytest.raises(ValueError, match="coalesc"):
        run_collective(tm, "rails", chunk_bytes=CHUNK, coalesce=True, fault_spec=spec)


# -- topology validation (satellite) -----------------------------------------


def test_rail_speeds_overprovisioned_allowed():
    topo = RailTopology(M, N, rail_speeds=[1.0, 2.0, 1.0, 1.0])
    assert topo.links["up:0:1"].rate == 2.0 * topo.r2
    tm = uniform_workload(M, N, bytes_per_pair=B)
    fast = run_collective(
        tm, "rails", chunk_bytes=CHUNK, rail_speeds=[2.0] * N, backend="event"
    )
    base = run_collective(tm, "rails", chunk_bytes=CHUNK, backend="event")
    assert fast.makespan < base.makespan


@pytest.mark.parametrize("bad", [[0.0, 1.0, 1.0, 1.0], [1.0, -0.5, 1.0, 1.0]])
def test_rail_speeds_must_be_positive(bad):
    with pytest.raises(ValueError, match="positive"):
        RailTopology(M, N, rail_speeds=bad)


def test_num_spines_optional_defaults():
    topo = RailTopology(3, 2)
    assert topo.num_spines == 3  # non-blocking default: one per domain
    topo = RailTopology(3, 2, num_spines=5)
    assert topo.num_spines == 5


# -- time-varying rates end to end -------------------------------------------


def test_step_degradation_slows_collective():
    tm = uniform_workload(M, N, bytes_per_pair=B)
    base = run_collective(tm, "rails", chunk_bytes=CHUNK, backend="event")
    spec = FaultSpec(rail_profiles={N - 1: step_profile(base.makespan / 3, 0.4)})
    assert not spec.is_static
    slow = run_collective(tm, "rails", chunk_bytes=CHUNK, fault_spec=spec)
    assert slow.makespan > base.makespan
    # Degrading after the run ends changes nothing.
    spec_late = FaultSpec(rail_profiles={N - 1: step_profile(base.makespan * 10, 0.4)})
    late = run_collective(tm, "rails", chunk_bytes=CHUNK, fault_spec=spec_late)
    assert late.makespan == base.makespan


# -- loss + go-back-N --------------------------------------------------------


class _DeliveryAudit:
    """Observer mirroring the go-back-N contract: every chunk delivered
    exactly once, never while an earlier chunk of its transport lane —
    (flow, source NIC), the per-rail QP — is lost and outstanding."""

    def __init__(self):
        self.delivered: dict[int, int] = {}
        self.outstanding: dict[tuple, set] = {}
        self.violations = 0

    def record_drop(self, link, t, job):
        lane = (job.flow_id, job.path[0])
        self.outstanding.setdefault(lane, set()).add(job.chunk_id)

    def record_completion(self, job, t):
        out = self.outstanding.get((job.flow_id, job.path[0]))
        if out and min(out) < job.chunk_id:
            self.violations += 1
        if out is not None:
            out.discard(job.chunk_id)
        self.delivered[job.chunk_id] = self.delivered.get(job.chunk_id, 0) + 1


@pytest.mark.parametrize("bursty", [False, True])
def test_loss_gbn_delivers_every_chunk_exactly_once(bursty):
    tm = uniform_workload(M, N, bytes_per_pair=B)
    loss = (
        LossConfig(rate=0.02, rto=3e-4, bad_rate=0.3, p_enter_bad=0.05, p_leave_bad=0.3)
        if bursty
        else LossConfig(rate=0.03, rto=3e-4)
    )
    topo = RailTopology(M, N, fault_spec=FaultSpec(loss=loss, seed=5))
    jobs = build_jobs(tm, CHUNK)
    num_chunks = sum(len(js) for js in jobs.values())
    audit = _DeliveryAudit()
    eng = Engine(topo, observers=(audit,))
    policy = make_policy("rails", topo)
    policy.prepare(jobs)
    res = eng.run(jobs, policy)
    dyn = res.dynamics
    # Every chunk delivered exactly once, in go-back-N order.
    assert sorted(audit.delivered) == list(range(num_chunks))
    assert set(audit.delivered.values()) == {1}
    assert audit.violations == 0
    assert dyn["delivered_chunks"] == num_chunks
    np.testing.assert_allclose(dyn["goodput_bytes"], tm.total_bytes(), rtol=1e-9)
    # The fault realization actually lost something, and retransmissions
    # paid extra wire bytes for it.
    assert dyn["drops"] > 0
    assert dyn["retransmits"] >= dyn["drops"]
    assert dyn["wire_bytes"] > 2 * tm.total_bytes() * 0.99  # 2 NIC hops/chunk


def test_loss_makes_collective_slower_and_is_seeded():
    tm = uniform_workload(M, N, bytes_per_pair=B)
    base = run_collective(tm, "rails", chunk_bytes=CHUNK, backend="event")
    spec = lambda: FaultSpec(loss=LossConfig(rate=0.02, rto=3e-4), seed=9)
    a = run_collective(tm, "rails", chunk_bytes=CHUNK, fault_spec=spec())
    b = run_collective(tm, "rails", chunk_bytes=CHUNK, fault_spec=spec())
    assert a.makespan > base.makespan
    assert a.makespan == b.makespan and a.cct == b.cct  # seeded determinism


def test_loss_config_validation():
    with pytest.raises(ValueError, match="rate"):
        LossConfig(rate=1.0, rto=1e-4)
    with pytest.raises(ValueError, match="rto"):
        LossConfig(rate=0.01, rto=0.0)
    with pytest.raises(ValueError, match="links"):
        LossConfig(rate=0.01, rto=1e-4, links="spineonly")
    # bad_rate without p_enter_bad > 0 would silently never burst.
    with pytest.raises(ValueError, match="p_enter_bad"):
        LossConfig(rate=0.01, rto=1e-4, bad_rate=0.5)


def test_feedback_estimator_shape_checked():
    tm = uniform_workload(M, N, bytes_per_pair=B)
    with pytest.raises(ValueError, match="rails"):
        run_streaming_collective(
            tm, "rails-online", chunk_bytes=CHUNK,
            feedback=RailHealthEstimator(2, nominal_rate=50e9),
        )


# -- PFC + ECN ---------------------------------------------------------------


class _PauseAudit:
    def __init__(self):
        self.intervals = []

    def record_pause(self, link, start, end):
        self.intervals.append((link, start, end))


def test_pfc_pause_creates_hol_blocking():
    # Receiver skew drives incast on the hot domain's down links.
    tm = receiver_skew_workload(M, N, total_bytes=B * 16, seed=1)
    base = run_collective(tm, "ecmp", chunk_bytes=CHUNK, backend="event")
    audit = _PauseAudit()
    spec = FaultSpec(pfc=PfcConfig(pause_bytes=3 * CHUNK))
    topo = RailTopology(M, N, fault_spec=spec)
    jobs = build_jobs(tm, CHUNK)
    eng = Engine(topo, observers=(audit,))
    res = eng.run(jobs, make_policy("ecmp", topo))
    assert audit.intervals, "pause thresholds were never crossed"
    assert all(end > start for _l, start, end in audit.intervals)
    assert res.dynamics["pause_time"] > 0
    # Head-of-line blocking can only delay the collective.
    assert res.makespan >= base.makespan * 0.999


def test_ecn_marks_and_sender_rate_cut():
    tm = receiver_skew_workload(M, N, total_bytes=B * 16, seed=1)
    spec = FaultSpec(ecn=EcnConfig(mark_bytes=2 * CHUNK, cut=0.7))
    m = run_collective(tm, "reps", chunk_bytes=CHUNK, seed=3, fault_spec=spec)
    base = run_collective(tm, "reps", chunk_bytes=CHUNK, seed=3, backend="event")
    # Marks happened and some sender took a multiplicative cut.
    topo = RailTopology(M, N, fault_spec=spec)
    jobs = build_jobs(tm, CHUNK)
    eng = Engine(topo, seed=3)
    res = eng.run(jobs, make_policy("reps", topo, seed=3))
    assert res.dynamics["ecn_marks"] > 0
    assert res.dynamics["min_sender_factor"] < 1.0
    # Pacing stretches the cut senders' serialization: never faster.
    assert res.makespan >= base.makespan * 0.999


def test_path_delay_reads_mark_and_pause_signals():
    spec = FaultSpec(
        pfc=PfcConfig(pause_bytes=4 * CHUNK), ecn=EcnConfig(mark_bytes=2 * CHUNK)
    )
    topo = RailTopology(M, N, fault_spec=spec)
    eng = Engine(topo)
    path = topo.rail_path(0, 1, 0)
    clean = eng.path_delay(path, src_domain=0)
    # A live pause assertion on the down link penalizes the path.
    eng.paused_links.add("down:1:0")
    paused = eng.path_delay(path, src_domain=0)
    assert paused > clean
    eng.paused_links.clear()
    # Recent (stale-snapshot) ECN marks penalize it too.
    eng._recent_marks = {"up:0:0": 8}
    marked = eng.path_delay(path, src_domain=0)
    assert marked > clean


def test_pfc_ecn_config_validation():
    with pytest.raises(ValueError, match="pause_bytes"):
        PfcConfig(pause_bytes=0.0)
    with pytest.raises(ValueError, match="resume"):
        PfcConfig(pause_bytes=100.0, resume_bytes=200.0)
    assert PfcConfig(pause_bytes=100.0).resume_bytes == 50.0
    with pytest.raises(ValueError, match="cut"):
        EcnConfig(mark_bytes=100.0, cut=1.5)


# -- EWMA tracking on a step profile (satellite) -----------------------------


def test_ewma_tracks_step_profile():
    """The health estimator must *track* a mid-run degradation: detect the
    step within a bounded number of observations and settle near truth."""
    stream = _stream(rounds=8)
    t_step = stream[3][0]
    slow = 0.5
    spec = FaultSpec(rail_profiles={N - 1: step_profile(t_step, slow)})
    est = RailHealthEstimator(N, nominal_rate=50e9, track_history=True)
    res = run_streaming_collective(
        stream, "rails-online", chunk_bytes=CHUNK, fault_spec=spec, feedback=est
    )
    assert res.health is est
    detect = est.time_to_detect(N - 1, slow, tol=0.15, after=t_step)
    assert detect is not None, "step never detected"
    seconds, observations = detect
    assert observations <= 30  # pinned: EWMA(0.3) needs ~6 obs for 85% settle
    assert seconds >= 0.0
    # Settled estimate within 20% of the true post-step speed.
    assert est.steady_state_error(N - 1, slow, tail=10) < 0.20
    # Healthy rails keep reading healthy.
    assert est.speeds()[0] > 0.8


def test_tracking_metrics_need_history():
    est = RailHealthEstimator(N, nominal_rate=50e9)
    with pytest.raises(ValueError, match="track_history"):
        est.time_to_detect(0, 0.5)
    with pytest.raises(ValueError, match="track_history"):
        est.steady_state_error(0, 0.5)


# -- plan-time profile pre-charge (satellite) --------------------------------


def test_straggler_precharge_from_profile():
    weights = np.full(64, 4.0 * 2**20)
    profile = step_profile(10.0, 0.5)
    speeds = [1.0, 1.0, 1.0, profile]
    # Planned before the step: the profile reads healthy.
    res_before, loads_b, _f, _i = degraded_rail_schedule(weights, 4, speeds, at_time=0.0)
    ref_before = degraded_rail_schedule(weights, 4, [1.0, 1.0, 1.0, 1.0])
    np.testing.assert_allclose(loads_b, ref_before[1])
    # Planned inside the degraded phase: pre-charge matches the scalar 0.5.
    _res, loads_a, _f, _i = degraded_rail_schedule(weights, 4, speeds, at_time=20.0)
    ref_after = degraded_rail_schedule(weights, 4, [1.0, 1.0, 1.0, 0.5])
    np.testing.assert_allclose(loads_a, ref_after[1])
    assert loads_a[3] < loads_a[0]
    np.testing.assert_allclose(speeds_at(speeds, 20.0), [1.0, 1.0, 1.0, 0.5])


def test_pipeline_threads_fault_spec():
    from repro.core.traffic import microbatch_stream
    from repro.sched import run_pipeline

    tms = microbatch_stream(M, N, 3, bytes_per_pair=B / 3, seed=4)
    clean = run_pipeline(tms, chunk_bytes=CHUNK, use_replay=False)
    spec = FaultSpec(loss=LossConfig(rate=0.02, rto=3e-4), seed=3)
    faulty = run_pipeline(tms, chunk_bytes=CHUNK, use_replay=False, fault_spec=spec)
    assert faulty.streaming.sim.dynamics["retransmits"] > 0
    assert faulty.makespan > clean.makespan


# -- the §VI-E fault regression ----------------------------------------------


def test_sec6e_rails_feedback_beats_reactive_under_faults():
    """Seeded 1% Gilbert–Elliott loss + one rail stepping to 0.5× mid-run:
    proactive rails-online with EWMA feedback completes the stream faster
    than the reactive baselines (the paper's §VI-E ordering), and faster
    than rails-online flying blind."""
    stream = _stream(rounds=6)
    t_mid = stream[3][0]

    def spec():
        return FaultSpec(
            rail_profiles={N - 1: step_profile(t_mid, 0.5)},
            loss=LossConfig(
                rate=0.01, rto=5e-4, bad_rate=0.25, p_enter_bad=0.02, p_leave_bad=0.3
            ),
            seed=11,
        )

    def run(pol, fb):
        return run_streaming_collective(
            stream, pol, chunk_bytes=CHUNK, fault_spec=spec(), feedback=fb
        )

    rails_fb = run("rails-online", True)
    rails_blind = run("rails-online", False)
    plb = run("plb", False)
    reps = run("reps", False)
    assert rails_fb.sim.dynamics["drops"] > 0  # the faults actually fired
    assert rails_fb.metrics.makespan < plb.metrics.makespan
    assert rails_fb.metrics.makespan < reps.metrics.makespan
    assert rails_fb.metrics.cct["p99"] < plb.metrics.cct["p99"]
    assert rails_fb.metrics.cct["p99"] < reps.metrics.cct["p99"]
    # Feedback is what closes the loop on the flapping rail.
    assert rails_fb.metrics.makespan < rails_blind.metrics.makespan
