"""Data pipeline: determinism, host sharding, packing, restart safety."""

import numpy as np
import pytest

from repro.data import DataConfig, SyntheticTokens, make_batch, pack_documents


def test_deterministic():
    cfg = DataConfig(1000, 64, 8, seed=3)
    a = SyntheticTokens(cfg).batch(5)
    b = SyntheticTokens(cfg).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_steps_differ():
    cfg = DataConfig(1000, 64, 8, seed=3)
    ds = SyntheticTokens(cfg)
    assert not np.array_equal(ds.batch(0)["tokens"], ds.batch(1)["tokens"])


def test_labels_shifted():
    cfg = DataConfig(1000, 64, 4)
    b = SyntheticTokens(cfg).batch(0)
    assert b["tokens"].shape == (4, 64)
    assert b["labels"].shape == (4, 64)


def test_host_sharding_disjoint_and_complete():
    """Multi-host shards reassemble into exactly the single-host batch."""
    whole = make_batch(DataConfig(1000, 32, 8, seed=7, num_hosts=1), step=2)
    sharded = make_batch(DataConfig(1000, 32, 8, seed=7, num_hosts=4), step=2)
    np.testing.assert_array_equal(whole["tokens"], sharded["tokens"])


def test_vocab_bounds():
    cfg = DataConfig(512, 128, 4)
    b = SyntheticTokens(cfg).batch(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 512


def test_uneven_hosts_rejected():
    with pytest.raises(ValueError):
        SyntheticTokens(DataConfig(100, 16, 7, num_hosts=2))


def test_pack_documents():
    docs = [np.array([1, 2, 3]), np.array([4, 5])]
    row = pack_documents(docs, 4)
    np.testing.assert_array_equal(row, [1, 2, 3, 4])
    row = pack_documents([np.array([9])], 4)
    np.testing.assert_array_equal(row, [9, 0, 0, 0])
