"""End-to-end integration: the real train driver on CPU (reduced configs),
checkpoint/restart equivalence, and the serve driver."""

import numpy as np
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_train_loss_decreases(tmp_path):
    out = train_mod.main(
        [
            "--arch", "deepseek-7b", "--reduced", "--steps", "30",
            "--batch", "4", "--seq", "64", "--microbatches", "2",
            "--lr", "3e-3", "--log-every", "5",
        ]
    )
    losses = dict(out["losses"])
    assert losses[29] < losses[0] - 0.3, losses


def test_train_moe_arch_runs(tmp_path):
    out = train_mod.main(
        [
            "--arch", "mixtral-8x7b", "--reduced", "--steps", "12",
            "--batch", "4", "--seq", "32", "--microbatches", "1",
            "--lr", "3e-3", "--log-every", "4",
        ]
    )
    assert np.isfinite(out["final_loss"])


def test_checkpoint_restart_resumes(tmp_path):
    ck = str(tmp_path / "ck")
    args = [
        "--arch", "xlstm-125m", "--reduced", "--steps", "10",
        "--batch", "2", "--seq", "32", "--microbatches", "1",
        "--ckpt-dir", ck, "--ckpt-every", "5", "--log-every", "1",
    ]
    full = train_mod.main(args)
    # second invocation restores at step 10 and does nothing more
    resumed = train_mod.main(args)
    assert resumed["losses"] == [] or resumed["final_loss"] is not None


def test_serve_generates(tmp_path):
    out = serve_mod.main(
        [
            "--arch", "gemma2-9b", "--reduced", "--batch", "2",
            "--prompt-len", "8", "--gen", "4",
        ]
    )
    assert out["tokens"].shape == (2, 4)
    assert (out["tokens"] >= 0).all()


def test_serve_deterministic_greedy():
    a = serve_mod.main(
        ["--arch", "deepseek-7b", "--reduced", "--batch", "1",
         "--prompt-len", "4", "--gen", "4", "--seed", "7"]
    )
    b = serve_mod.main(
        ["--arch", "deepseek-7b", "--reduced", "--batch", "1",
         "--prompt-len", "4", "--gen", "4", "--seed", "7"]
    )
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
