"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU asserting output shapes + no NaNs (the assignment's smoke contract),
plus a decode step and prefill/forward consistency."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import decode_fn, init_cache, init_params, loss_fn, prefill_fn

ARCHS = list_archs()
B, T = 2, 32


def _batch(cfg, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    batch = {
        "tokens": jax.random.randint(k1, (B, T), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (B, T), 0, cfg.vocab_size),
    }
    if cfg.is_encoder_decoder:
        batch["embeds"] = (
            jax.random.normal(k1, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
        ).astype(jnp.bfloat16)
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, b))(params, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    # one SGD step moves the loss (gradients flow end to end)
    grads = jax.grad(lambda p: loss_fn(p, cfg, _batch(cfg))[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, B, 64)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = jax.jit(lambda p, c, t: decode_fn(p, cfg, c, t, 3))(
        params, cache, tok
    )
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["deepseek-7b", "gemma2-9b", "mixtral-8x7b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode logits == full-forward logits (teacher forcing).

    MoE archs compare with capacity high enough that the training dispatch
    path drops nothing — decode uses the drop-free dense-EP path, so drops
    are the one *expected* train/decode divergence.
    """
    import dataclasses

    cfg = get_config(arch).reduced()
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    params = init_params(cfg, jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab_size)
    # full forward last-position logits at each prefix, via prefill_fn
    from repro.models.transformer import forward_hidden, logits_last

    cache = init_cache(cfg, 1, 8)
    dec = jax.jit(lambda p, c, t, pos: decode_fn(p, cfg, c, t, pos))
    for pos in range(8):
        logits_dec, cache = dec(params, cache, toks[:, pos : pos + 1], pos)
    hidden, _, _ = forward_hidden(params, cfg, {"tokens": toks})
    logits_fwd = logits_last(params, cfg, hidden)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_fwd, np.float32),
        atol=0.12,  # bf16 accumulation differences across the stack
        rtol=0.12,
    )


def test_sliding_window_changes_output():
    cfg = get_config("h2o-danube-3-4b").reduced()
    assert cfg.sliding_window == 16
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, T), 0, cfg.vocab_size)
    from repro.models.transformer import forward_hidden

    h_win, _, _ = forward_hidden(params, cfg, {"tokens": toks})
    import dataclasses

    cfg_full = dataclasses.replace(cfg, attn_pattern="full", sliding_window=None)
    h_full, _, _ = forward_hidden(params, cfg_full, {"tokens": toks})
    assert not np.allclose(np.asarray(h_win, np.float32), np.asarray(h_full, np.float32))


def test_gemma2_softcaps_applied():
    cfg = get_config("gemma2-9b").reduced()
    assert cfg.attn_logit_softcap and cfg.final_logit_softcap
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, 1, 8)
    logits, _ = decode_fn(params, cfg, cache, jnp.ones((1, 1), jnp.int32), 0)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_logit_softcap + 1e-3


def test_param_counts_match_public_sizes():
    """Full configs land near their public parameter counts."""
    expected = {
        "qwen2-vl-72b": 72e9,
        "deepseek-7b": 7e9,
        "mixtral-8x7b": 46.7e9,
        "gemma2-9b": 9e9,
        "qwen3-moe-30b-a3b": 30e9,
        "phi4-mini-3.8b": 3.8e9,
        "whisper-small": 0.24e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert 0.6 * want <= got <= 1.45 * want, (arch, got, want)


def test_mixtral_active_params():
    cfg = get_config("mixtral-8x7b")
    active = cfg.active_param_count()
    assert 10e9 <= active <= 16e9  # ~12.9B active (top-2 of 8)
