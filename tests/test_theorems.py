"""Executable Theorems 1-4 (paper §IV)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.lp import closed_form_opt, loads_from_allocation
from repro.core.theorems import (
    theorem1_capacity,
    theorem1_maxflow_check,
    theorem2_lower_bound,
    theorem2_optimal_time,
    theorem3_check_symmetry,
)


@pytest.mark.parametrize("m,n", [(2, 2), (3, 4), (4, 8)])
def test_theorem1_maxflow_equals_n_r2(m, n):
    """Max flow on the explicit rail graph == N * R2 (Theorem 1)."""
    r1, r2 = 10.0, 1.0
    assert theorem1_maxflow_check(m, n, r1, r2) == pytest.approx(
        theorem1_capacity(n, r1, r2)
    )


def test_theorem1_requires_r1_gt_r2():
    with pytest.raises(ValueError):
        theorem1_capacity(4, 1.0, 1.0)


def test_theorem1_intra_domain_bottleneck():
    """If R1 < R2 the max-flow drops below N*R2 — the premise matters."""
    # With slow intra-domain fabric the GPU->NIC edges throttle the flow.
    val = theorem1_maxflow_check(2, 4, r1=0.5, r2=1.0)
    assert val < 4.0


@settings(max_examples=50, deadline=None)
@given(m=st.integers(2, 6), n=st.integers(2, 8), seed=st.integers(0, 99))
def test_theorem3_symmetry_property(m, n, seed):
    """Uniform send => uniform receive for any traffic matrix (Theorem 3)."""
    rng = np.random.default_rng(seed)
    d2 = rng.uniform(0, 100, (m, m))
    np.fill_diagonal(d2, 0)
    res = theorem3_check_symmetry(d2, n)
    assert res["uniform"], res


@settings(max_examples=50, deadline=None)
@given(m=st.integers(2, 5), n=st.integers(2, 6), seed=st.integers(0, 99))
def test_theorem2_uniform_attains_lower_bound(m, n, seed):
    """P*=1/N attains the Theorem-2 min-max lower bound exactly."""
    rng = np.random.default_rng(seed)
    d2 = rng.uniform(0, 50, (m, m))
    np.fill_diagonal(d2, 0)
    p_star, _ = closed_form_opt(d2, n)
    t_opt = theorem2_optimal_time(d2, n, r2=1.0)
    t_of_pstar = theorem2_lower_bound(d2, p_star, r2=1.0)
    np.testing.assert_allclose(t_of_pstar, t_opt, rtol=1e-12)


@settings(max_examples=50, deadline=None)
@given(m=st.integers(2, 4), n=st.integers(2, 5), seed=st.integers(0, 99))
def test_theorem2_any_allocation_is_no_better(m, n, seed):
    """No (random) allocation beats the closed-form optimum."""
    rng = np.random.default_rng(seed)
    d2 = rng.uniform(0, 50, (m, m))
    np.fill_diagonal(d2, 0)
    p = rng.dirichlet(np.ones(n), size=(m, m))  # random valid allocation
    t_opt = theorem2_optimal_time(d2, n, r2=1.0)
    assert theorem2_lower_bound(d2, p, r2=1.0) >= t_opt - 1e-9
