"""MoE layer: gating, capacity, local-vs-distributed equivalence."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.moe import _gate, moe_apply, moe_init

from helpers import run_multidevice

CFG = get_config("mixtral-8x7b").reduced()  # 4 experts, top-2


def _params(cfg, seed=0):
    return moe_init(jax.random.PRNGKey(seed), cfg, jnp.float32)


def test_gate_counts_and_weights():
    params = _params(CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, CFG.d_model))
    idx, w, aux, counts = _gate(x, params["router"], CFG)
    assert idx.shape == (64, 2) and w.shape == (64, 2)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(counts.sum()) == 64 * 2
    assert float(aux) >= 1.0 - 1e-6  # aux loss >= 1 (uniform optimum)


def test_moe_apply_shapes_and_counts():
    params = _params(CFG)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, CFG.d_model))
    out, aux, counts = moe_apply(params, CFG, x)
    assert out.shape == x.shape
    assert counts.shape == (CFG.num_experts,)
    assert int(counts.sum()) == 2 * 32 * CFG.experts_per_token
    assert bool(jnp.all(jnp.isfinite(out)))


def test_dense_small_path_no_drops():
    """Decode-sized inputs take the dense path: identical token counts in
    == weighted expert mix out, no capacity drops."""
    params = _params(CFG)
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 1, CFG.d_model))
    out, _aux, counts = moe_apply(params, CFG, x)
    assert out.shape == x.shape
    assert int(counts.sum()) == 3 * CFG.experts_per_token


def test_capacity_dropping_monotone():
    """Lower capacity factor -> no more output mass (dropped tokens)."""
    import dataclasses

    params = _params(CFG)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 64, CFG.d_model))
    hi = dataclasses.replace(CFG, capacity_factor=8.0)
    lo = dataclasses.replace(CFG, capacity_factor=0.25)
    out_hi, _, _ = moe_apply(params, hi, x)
    out_lo, _, _ = moe_apply(params, lo, x)
    assert float(jnp.abs(out_lo).sum()) <= float(jnp.abs(out_hi).sum()) + 1e-3


def test_high_capacity_matches_dense_reference():
    """With capacity high enough to never drop, the dispatch path must equal
    the dense-EP reference computation exactly."""
    import dataclasses

    from repro.models.moe import _moe_dense_small

    cfg = dataclasses.replace(CFG, capacity_factor=float(CFG.num_experts))
    params = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 32, cfg.d_model))
    out_dispatch, _, _ = moe_apply(params, cfg, x)
    out_dense, _, _ = _moe_dense_small(x.reshape(32, -1), params, cfg)
    np.testing.assert_allclose(
        np.asarray(out_dispatch).reshape(32, -1), np.asarray(out_dense),
        atol=2e-4, rtol=2e-4,
    )


@pytest.mark.parametrize("mode", ["dense", "rails", "spray", "ring"])
def test_distributed_matches_local(mode):
    """shard_map EP path == single-device path, for every dispatch mode."""
    out = run_multidevice(
        f"""
        import numpy as np, dataclasses
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.configs import get_config
        from repro.models.moe import moe_apply, moe_init, EpInfo

        cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                                  dispatch_mode="{mode}", num_rails=2,
                                  dispatch_chunks=2)
        params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 64, cfg.d_model))
        ref, _, ref_counts = moe_apply(params, cfg, x)

        from repro import compat
        mesh = compat.make_mesh((2, 4), ("data", "expert"))
        ep = EpInfo(mesh, "expert", 4)
        with mesh:
            out, _, counts = jax.jit(
                lambda p, xx: moe_apply(p, cfg, xx, ep)
            )(params, x)
        err = float(jnp.abs(out - ref).max())
        assert err < 2e-4, err
        assert (np.asarray(counts) == np.asarray(ref_counts)).all()
        print("OK", err)
        """,
        devices=8,
    )
    assert "OK" in out
