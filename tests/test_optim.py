"""Optimizer substrate: AdamW, schedules, int8 EF compression."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compressed_psum,
    dequantize_int8,
    ef_init,
    quantize_int8,
    warmup_cosine,
)

from helpers import run_multidevice


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array([2.0])}
    cfg = AdamWConfig(learning_rate=0.1, weight_decay=0.0)
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw_update(grads, state, params, cfg)
    assert float(loss(params)) < 1e-3


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(learning_rate=1.0, grad_clip=1.0, weight_decay=0.0)
    state = adamw_init(params)
    huge = {"w": jnp.full(3, 1e9)}
    _, _, stats = adamw_update(huge, state, params, cfg)
    assert float(stats["grad_norm"]) > 1e8  # norm observed pre-clip


def test_bf16_params_fp32_moments():
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    state = adamw_init(params)
    assert state["m"]["w"].dtype == jnp.float32
    new_p, state, _ = adamw_update(
        {"w": jnp.ones(4, jnp.bfloat16)}, state, params, AdamWConfig()
    )
    assert new_p["w"].dtype == jnp.bfloat16


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert abs(float(sched(jnp.int32(10))) - 1.0) < 0.11
    assert float(sched(jnp.int32(100))) <= 0.11


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3, 1000), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_steps():
    """EF property: accumulated quantization error stays bounded (the bias
    doesn't grow), so the long-run average update is the true gradient."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(0, 1, 256), jnp.float32)
    ef = jnp.zeros(256)
    applied = jnp.zeros(256)
    for _ in range(50):
        c = g_true + ef
        q, s = quantize_int8(c)
        deq = dequantize_int8(q, s)
        applied = applied + deq
        ef = c - deq
    avg = applied / 50
    np.testing.assert_allclose(np.asarray(avg), np.asarray(g_true), atol=2e-2)


def test_compressed_psum_multidevice():
    out = run_multidevice(
        """
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.optim import compressed_psum, ef_init

        from repro import compat
        mesh = compat.make_mesh((4,), ("pod",))
        g = np.random.default_rng(0).normal(size=(4, 64)).astype(np.float32)

        @partial(compat.shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")), out_specs=(P("pod"), P("pod")))
        def sync(g_loc, ef_loc):
            gr = {"w": g_loc[0]}
            efr = {"w": ef_loc[0]}
            avg, ef2 = compressed_psum(gr, efr, "pod", 4)
            return avg["w"][None], ef2["w"][None]

        ef = np.zeros_like(g)
        avg, ef2 = jax.jit(sync)(g, ef)
        true_avg = g.mean(axis=0)
        err = np.abs(np.asarray(avg)[0] - true_avg).max()
        assert err < 0.05, err
        # int8 collective visible in HLO
        hlo = jax.jit(sync).lower(g, ef).compile().as_text()
        assert "s32" in hlo or "s8" in hlo
        print("OK", err)
        """,
        devices=4,
    )
    assert "OK" in out
