"""Flow splitting + spray plans (paper §V) — incl. hypothesis properties."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.lp import closed_form_opt
from repro.core.plan import (
    build_all_plans,
    build_spray_plan,
    plan_quality,
    split_message,
    split_traffic_row,
)
from repro.core.traffic import sparse_topk_workload, uniform_workload


@settings(max_examples=100, deadline=None)
@given(size=st.floats(0.1, 1e4), chunk=st.floats(5.0, 1e4))
def test_split_conserves_bytes_and_caps_wmax(size, chunk):
    # ranges bounded so size/chunk (= number of atomic flows) stays ~2000
    flows = split_message(size, chunk, 0, 1)
    np.testing.assert_allclose(sum(f.size for f in flows), size, rtol=1e-9)
    assert all(f.size <= chunk + 1e-9 for f in flows)
    # reassembly metadata: seq covers 0..len-1
    assert sorted(f.seq for f in flows) == list(range(len(flows)))


def test_split_traffic_row_skips_intra_domain():
    tm = uniform_workload(4, 2, bytes_per_pair=8.0, include_self=True)
    flows = split_traffic_row(tm.d1[1], 1, chunk_bytes=4.0)
    assert all(f.dst_domain != 1 for f in flows)


def test_plan_bound_and_conservation():
    tm = sparse_topk_workload(6, 4, sparsity=0.4, seed=2)
    plans = build_all_plans(tm.d1, chunk_bytes=64.0)
    for plan in plans:
        assert plan.bound_holds()
        np.testing.assert_allclose(
            plan.loads.sum(), sum(f.size for f in plan.flows), rtol=1e-9
        )


def test_distributed_plans_reach_global_optimum():
    """Theorem 3 operationalized: independent per-sender LPT plans achieve
    the global min-max optimum when chunks are fine enough."""
    tm = uniform_workload(6, 4, bytes_per_pair=16.0)
    plans = build_all_plans(tm.d1, chunk_bytes=4.0)
    q = plan_quality(plans, 4)
    _, t_star = closed_form_opt(tm.d2, 4)
    assert q["max_load"] <= t_star * 1.05  # within 5% of optimum


def test_finer_chunks_improve_balance():
    tm = sparse_topk_workload(6, 4, sparsity=0.5, seed=7)
    coarse = plan_quality(build_all_plans(tm.d1, chunk_bytes=1e9), 4)
    fine = plan_quality(build_all_plans(tm.d1, chunk_bytes=16.0), 4)
    assert fine["max_load"] <= coarse["max_load"] + 1e-9


def test_policy_comparison_lpt_best():
    tm = sparse_topk_workload(6, 4, sparsity=0.5, seed=3)
    flows = split_traffic_row(tm.d1[0], 0, chunk_bytes=32.0)
    lpt = build_spray_plan(flows, 4, 0, policy="lpt")
    rr = build_spray_plan(flows, 4, 0, policy="round_robin")
    rnd = build_spray_plan(flows, 4, 0, policy="random")
    assert lpt.loads.max() <= rr.loads.max() + 1e-9
    assert lpt.loads.max() <= rnd.loads.max() + 1e-9
